"""repro-lint: the invariant linter itself (DESIGN.md §20).

Fixture-driven true-positive/true-negative snippets for all five passes,
baseline add/expire semantics, the CLI's exit-code contract, and a
self-lint asserting the real repo is clean modulo the justified baseline.
Also locks the accounting fix the linter surfaced (L401: faults_injected/
degraded/readback_retries were unbilled until this PR).
"""

import os
import sys
import textwrap

import pytest

from repro.lint import (Context, PASSES, load_baseline, run_passes,
                        split_by_baseline, write_baseline)
from repro.lint.base import Finding

REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def lint_files(tmp_path, files, passes=None):
    """Write a mini-repo ({relpath: source}) and run the passes on it."""
    for rel, src in files.items():
        p = tmp_path / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(textwrap.dedent(src))
    ctx = Context(str(tmp_path), list(files))
    return run_passes(ctx, passes)


def rules(findings):
    return sorted({f.rule for f in findings})


# -- trace purity (L101-L105) -------------------------------------------------


class TestTracePurity:
    def test_true_positives_all_rules(self, tmp_path):
        fs = lint_files(tmp_path, {"src/repro/serve/hot.py": """
            import jax
            import numpy as np

            @jax.jit
            def tick(params, st):
                if st.sum() > 0:            # L104
                    y = float(st.mean())    # L102
                z = np.asarray(st)          # L103
                print(st)                   # L105
                return st.item()            # L101
        """}, ["trace-purity"])
        assert rules(fs) == ["L101", "L102", "L103", "L104", "L105"]
        assert all(f.path == "src/repro/serve/hot.py" for f in fs)
        assert all(f.func == "tick" for f in fs)

    def test_true_negative_static_constructs(self, tmp_path):
        # shape branches, `is None`, len(), static_argnames params, and
        # host work on closure config are all legal inside jit
        fs = lint_files(tmp_path, {"src/repro/serve/hot.py": """
            import functools
            import jax
            import jax.numpy as jnp
            import numpy as np

            @functools.partial(jax.jit, static_argnames=("k",))
            def tick(params, st, k):
                if st.shape[1] > 0:          # shapes are static
                    st = st + 1
                if k > 2:                    # static_argnames param
                    st = st * 2
                if params is None:           # identity check is static
                    params = jnp.zeros(())
                n = len(st)                  # len() is static
                host = np.zeros(int(n))     # np on static values only
                return st + jnp.asarray(host)
        """}, ["trace-purity"])
        assert fs == []

    def test_interprocedural_taint_reaches_callee(self, tmp_path):
        fs = lint_files(tmp_path, {"src/repro/serve/hot.py": """
            import jax

            def helper(x, cfg):
                if cfg:              # untainted: called with a constant
                    x = x + 1
                return x.item()      # L101: x IS the traced arg

            @jax.jit
            def tick(st):
                return helper(st, True)
        """}, ["trace-purity"])
        assert rules(fs) == ["L101"]
        assert fs[0].func == "helper"

    def test_factory_returned_ticks_are_roots(self, tmp_path):
        # the engine idiom: jax.jit(self._make_impl(k)) — the functions
        # the factory returns (incl. via `a if c else b`) are jit roots
        fs = lint_files(tmp_path, {"src/repro/serve/hot.py": """
            import jax

            class Eng:
                def _make_impl(self, k):
                    def tick_a(st):
                        return st.item()     # L101, root via factory
                    def tick_b(st):
                        return st + 1
                    if k > 2:
                        return tick_a
                    return tick_b if k else tick_a

                def build(self, k):
                    return jax.jit(self._make_impl(k), donate_argnums=(0,))
        """}, ["trace-purity"])
        assert rules(fs) == ["L101"]

    def test_closure_taint_flows_into_nested_helper(self, tmp_path):
        fs = lint_files(tmp_path, {"src/repro/serve/hot.py": """
            import jax

            @jax.jit
            def tick(st):
                def finisher():
                    return float(st.sum())   # L102 via closure
                return finisher()
        """}, ["trace-purity"])
        assert rules(fs) == ["L102"]


# -- readback budget (L201-L203) ----------------------------------------------


ENGINE_PREAMBLE = """
    import jax
    import numpy as np

    class ServeEngine:
        def _readback(self, x):
            return np.asarray(jax.device_get(x))

        def _checked_readback(self, x):
            for _ in range(3):
                out = self._readback(x)
            return out
"""


class TestReadbackBudget:
    def test_double_readback_on_one_path_flags(self, tmp_path):
        fs = lint_files(tmp_path, {"src/repro/serve/engine.py":
                                   ENGINE_PREAMBLE + """
        def step(self):
            a = self._checked_readback(self.state)
            b = self._checked_readback(self.state)   # second on same path
            return a, b
        """}, ["readback-budget"])
        assert "L201" in rules(fs)

    def test_exclusive_branches_take_max_not_sum(self, tmp_path):
        # one readback per if/elif/else arm == budget 1: the real step()
        fs = lint_files(tmp_path, {"src/repro/serve/engine.py":
                                   ENGINE_PREAMBLE + """
        def step(self):
            if self.tree:
                out = self._checked_readback(self.a)
            elif self.spec:
                out = self._checked_readback(self.b)
            else:
                out = self._checked_readback(self.c)
            return out
        """}, ["readback-budget"])
        assert fs == []

    def test_readback_inside_loop_flags(self, tmp_path):
        fs = lint_files(tmp_path, {"src/repro/serve/engine.py":
                                   ENGINE_PREAMBLE + """
        def step(self):
            outs = []
            for s in self.slots:
                outs.append(self._readback(s))   # per-slot readback
            return outs
        """}, ["readback-budget"])
        assert "L202" in rules(fs)

    def test_train_run_loop_readback_is_legal(self, tmp_path):
        # TrainEngine.run's ONE per-tick readback lives in the step loop;
        # its scope allows loop depth 1
        fs = lint_files(tmp_path, {"src/repro/train/engine.py": """
            import jax

            class TrainEngine:
                def run(self, n):
                    for _ in range(n):
                        ms = self._tick(self.params)
                        ms_host = jax.device_get(ms)
                    return ms_host
        """}, ["readback-budget"])
        assert fs == []

    def test_raw_device_get_outside_funnel_flags(self, tmp_path):
        fs = lint_files(tmp_path, {"src/repro/serve/engine.py":
                                   ENGINE_PREAMBLE + """
        def step(self):
            return self._checked_readback(self.state)

        def peek(self):
            return jax.device_get(self.state)    # escapes host_readbacks
        """}, ["readback-budget"])
        assert rules(fs) == ["L203"]
        assert fs[0].func == "ServeEngine.peek"


# -- replay determinism (L301-L303) -------------------------------------------


class TestReplayDeterminism:
    def test_wall_clock_and_unseeded_rng_flag(self, tmp_path):
        fs = lint_files(tmp_path, {"src/repro/serve/snapshot.py": """
            import time
            import numpy as np

            def append_tick(journal, rec):
                rec["t"] = time.time()                 # L301
                rec["jitter"] = np.random.default_rng().random()   # L302
                journal.write(rec)
        """}, ["replay-determinism"])
        assert rules(fs) == ["L301", "L302"]

    def test_monotonic_and_seeded_rng_are_legal(self, tmp_path):
        fs = lint_files(tmp_path, {"src/repro/serve/snapshot.py": """
            import time
            import numpy as np

            def append_tick(journal, rec):
                t0 = time.monotonic()        # measurement, never replayed
                rng = np.random.default_rng(0)
                rec["jitter"] = rng.random()
                journal.write(rec)
        """}, ["replay-determinism"])
        assert fs == []

    def test_set_iteration_into_journal_flags(self, tmp_path):
        fs = lint_files(tmp_path, {"src/repro/serve/snapshot.py": """
            def host_state_dict(eng):
                fit = set()
                return {"fit_checked": [int(u) for u in fit]}   # L303
        """}, ["replay-determinism"])
        assert rules(fs) == ["L303"]

    def test_sorted_set_iteration_is_legal(self, tmp_path):
        fs = lint_files(tmp_path, {"src/repro/serve/snapshot.py": """
            def host_state_dict(eng):
                fit = set()
                return {"fit_checked": sorted(int(u) for u in fit)}
        """}, ["replay-determinism"])
        assert fs == []

    def test_unsorted_dict_items_into_record_flags(self, tmp_path):
        fs = lint_files(tmp_path, {"src/repro/serve/pages.py": """
            class PagePool:
                def state_dict(self):
                    return {"registry": [[k, v] for k, v in
                                         self._page_key.items()]}   # L303
        """}, ["replay-determinism"])
        assert rules(fs) == ["L303"]

    def test_dict_comprehension_is_legal(self, tmp_path):
        # JSON objects are key-addressed: emitting a dict is order-safe
        fs = lint_files(tmp_path, {"src/repro/serve/pages.py": """
            class PagePool:
                def state_dict(self):
                    return {"depth": {str(k): v for k, v in
                                      self._depth.items()}}
        """}, ["replay-determinism"])
        assert fs == []

    def test_out_of_scope_module_not_flagged(self, tmp_path):
        # wall-clock in launch/ tooling is not on the replay path
        fs = lint_files(tmp_path, {"src/repro/launch/dryrun.py": """
            import time

            def stamp():
                return time.time()
        """}, ["replay-determinism"])
        assert fs == []


# -- accounting completeness (L401-L402) --------------------------------------


METRICS_MOD = """
    import dataclasses

    @dataclasses.dataclass
    class StepMetrics:
        tokens: int
        wall_s: float
        kv_bytes: float = 0.0
        mystery_j: float = 0.0      # the half-wired field under test
        queue_depth: int = 0

    ACCOUNTING_EXEMPT = frozenset({"queue_depth"})
"""


def accountant_mod(bill_mystery):
    extra = ('self._x += float(getattr(metrics, "mystery_j", 0.0))\n'
             if bill_mystery else "pass\n")
    return """
    class CarbonAccountant:
        def observe_serve(self, metrics):
            self._t += float(metrics.tokens)
            self._w += float(metrics.wall_s)
            self._b += float(getattr(metrics, "kv_bytes", 0.0))
            """ + extra


class TestAccountingCompleteness:
    def test_half_wired_field_flags(self, tmp_path):
        fs = lint_files(tmp_path, {
            "src/repro/serve/engine.py": METRICS_MOD,
            "src/repro/core/accounting.py": accountant_mod(False),
        }, ["accounting-completeness"])
        assert rules(fs) == ["L401"]
        assert "mystery_j" in fs[0].detail

    def test_billed_and_exempt_fields_pass(self, tmp_path):
        fs = lint_files(tmp_path, {
            "src/repro/serve/engine.py": METRICS_MOD,
            "src/repro/core/accounting.py": accountant_mod(True),
        }, ["accounting-completeness"])
        assert fs == []

    def test_unguarded_division_flags(self, tmp_path):
        fs = lint_files(tmp_path, {"src/repro/core/accounting.py": """
            class CarbonAccountant:
                def observe_serve(self, metrics):
                    pass

                def report(self):
                    return {"j_per_token": self._j / self._tokens}  # L402
        """}, ["accounting-completeness"])
        assert rules(fs) == ["L402"]

    def test_guarded_divisions_pass(self, tmp_path):
        fs = lint_files(tmp_path, {"src/repro/core/accounting.py": """
            class CarbonAccountant:
                def observe_serve(self, metrics):
                    pass

                def report(self):
                    return {
                        "a": self._j / self._tokens
                             if self._tokens > 0 else 0.0,   # IfExp guard
                        "b": self._j / 1e6,                  # literal
                        "c": self._j / max(self._steps, 1),  # max() guard
                    }

                def train_report(self):
                    if self._train_steps == 0:
                        return None
                    n = self._train_steps
                    return {"per_step": self._j / n}   # early-return guard
        """}, ["accounting-completeness"])
        assert fs == []


# -- donation safety (L501) ---------------------------------------------------


class TestDonationSafety:
    def test_use_after_donate_flags(self, tmp_path):
        fs = lint_files(tmp_path, {"src/repro/serve/hot.py": """
            import jax

            def _impl(params, st):
                return st + 1

            _tick = jax.jit(_impl, donate_argnums=(1,))

            def run(params, state):
                out = _tick(params, state)
                return state.sum()     # L501: state's buffer is gone
        """}, ["donation-safety"])
        assert rules(fs) == ["L501"]
        assert "state" in fs[0].detail

    def test_same_statement_rebinding_is_safe(self, tmp_path):
        # the engine convention: self.state, out = self._tick(..., self.state)
        fs = lint_files(tmp_path, {"src/repro/serve/hot.py": """
            import jax

            class Eng:
                def _donate(self):
                    return (1,)

                def _build(self):
                    def impl(params, st):
                        return st, st.sum()
                    fn = jax.jit(impl, donate_argnums=self._donate())
                    return fn

                def setup(self):
                    self._tick = self._build()

                def step(self):
                    self.state, out = self._tick(self.params, self.state)
                    return out, self.state.shape
        """}, ["donation-safety"])
        assert fs == []

    def test_factory_call_call_use_after_donate_flags(self, tmp_path):
        # self._admit_exe(b)(params, state): donation via factory result
        fs = lint_files(tmp_path, {"src/repro/serve/hot.py": """
            import jax

            class Eng:
                def _admit_exe(self, b):
                    def admit(params, st):
                        return st
                    fn = jax.jit(admit, donate_argnums=(1,))
                    return fn

                def step(self):
                    new = self._admit_exe(4)(self.params, self.state)
                    junk = self.state.sum()    # L501
                    self.state = new
                    return junk
        """}, ["donation-safety"])
        assert rules(fs) == ["L501"]


# -- baseline semantics -------------------------------------------------------


class TestBaseline:
    def _findings(self):
        return [Finding("L301", "src/repro/serve/snapshot.py", 10,
                        "append_tick", "wall-clock `time.time`"),
                Finding("L303", "src/repro/serve/pages.py", 20,
                        "PagePool.state_dict", "set iteration")]

    def test_add_suppresses_and_expire_warns(self, tmp_path):
        fs = self._findings()
        path = str(tmp_path / "baseline.txt")
        write_baseline(path, fs)
        baseline = load_baseline(path)
        assert len(baseline) == 2
        new, supp, stale = split_by_baseline(fs, baseline)
        assert new == [] and len(supp) == 2 and stale == []
        # the violation behind entry 0 gets fixed -> its entry goes stale
        new, supp, stale = split_by_baseline(fs[1:], baseline)
        assert new == [] and len(supp) == 1
        assert stale == [fs[0].fingerprint]

    def test_new_finding_not_suppressed(self, tmp_path):
        fs = self._findings()
        path = str(tmp_path / "baseline.txt")
        write_baseline(path, fs[:1])
        new, supp, stale = split_by_baseline(fs, load_baseline(path))
        assert [f.rule for f in new] == ["L303"]

    def test_fingerprint_survives_line_drift(self):
        a = Finding("L301", "m.py", 10, "f", "wall-clock `time.time`")
        b = Finding("L301", "m.py", 99, "f", "wall-clock `time.time`")
        assert a.fingerprint == b.fingerprint

    def test_justifications_parse(self, tmp_path):
        p = tmp_path / "b.txt"
        p.write_text("# header comment\n\n"
                     "L301:m.py:f:slug  # heartbeat is wall-clock\n")
        assert load_baseline(str(p)) == {
            "L301:m.py:f:slug": "heartbeat is wall-clock"}


# -- the CLI ------------------------------------------------------------------


class TestCli:
    def _cli(self):
        sys.path.insert(0, os.path.join(REPO_ROOT, "tools"))
        try:
            import repro_lint
        finally:
            sys.path.pop(0)
        return repro_lint

    def test_clean_repo_exits_zero_and_seeded_violation_fails(self, tmp_path):
        cli = self._cli()
        (tmp_path / "src/repro/serve").mkdir(parents=True)
        eng = tmp_path / "src/repro/serve/hot.py"
        eng.write_text("import jax\n\n@jax.jit\ndef tick(st):\n"
                       "    return st + 1\n")
        assert cli.main(["--root", str(tmp_path)]) == 0
        # seed the synthetic violation the CI lint job must catch
        eng.write_text("import jax\n\n@jax.jit\ndef tick(st):\n"
                       "    return st.item()\n")
        assert cli.main(["--root", str(tmp_path)]) == 1

    def test_write_baseline_then_clean_then_strict_stale(self, tmp_path, capsys):
        cli = self._cli()
        (tmp_path / "src/repro/serve").mkdir(parents=True)
        eng = tmp_path / "src/repro/serve/hot.py"
        eng.write_text("import jax\n\n@jax.jit\ndef tick(st):\n"
                       "    return st.item()\n")
        base = str(tmp_path / "baseline.txt")
        assert cli.main(["--root", str(tmp_path), "--baseline", base,
                         "--write-baseline"]) == 0
        assert cli.main(["--root", str(tmp_path), "--baseline", base]) == 0
        # fix the violation: entry goes stale; --strict turns that red
        eng.write_text("import jax\n\n@jax.jit\ndef tick(st):\n"
                       "    return st + 1\n")
        assert cli.main(["--root", str(tmp_path), "--baseline", base]) == 0
        assert "stale" in capsys.readouterr().out
        assert cli.main(["--root", str(tmp_path), "--baseline", base,
                         "--strict"]) == 1

    def test_report_artifact_schema(self, tmp_path):
        import json
        cli = self._cli()
        (tmp_path / "src/repro/serve").mkdir(parents=True)
        (tmp_path / "src/repro/serve/hot.py").write_text(
            "import jax\n\n@jax.jit\ndef tick(st):\n    return st.item()\n")
        rpt = str(tmp_path / "findings.json")
        assert cli.main(["--root", str(tmp_path), "--report", rpt]) == 1
        payload = json.load(open(rpt))
        assert payload["total"] == 1
        assert payload["new"][0]["rule"] == "L101"
        assert payload["new"][0]["fingerprint"].startswith("L101:")

    def test_unknown_pass_is_usage_error(self, tmp_path):
        cli = self._cli()
        assert cli.main(["--root", str(tmp_path),
                         "--passes", "no-such-pass"]) == 2


# -- self-lint: the repo is clean modulo the justified baseline ---------------


class TestSelfLint:
    def test_repo_clean_modulo_baseline(self):
        ctx = Context.for_root(REPO_ROOT)
        findings = run_passes(ctx)
        baseline = load_baseline(
            os.path.join(REPO_ROOT, "tools", "lint_baseline.txt"))
        new, _supp, stale = split_by_baseline(findings, baseline)
        assert new == [], "new lint findings:\n" + "\n".join(
            f.render() for f in new)
        assert stale == [], f"stale baseline entries (delete them): {stale}"

    def test_baseline_is_small_and_justified(self):
        baseline = load_baseline(
            os.path.join(REPO_ROOT, "tools", "lint_baseline.txt"))
        assert 0 < len(baseline) <= 5
        for fp, why in baseline.items():
            assert why, f"baseline entry lacks a justification: {fp}"

    def test_all_five_passes_registered(self):
        assert sorted(PASSES) == [
            "accounting-completeness", "donation-safety",
            "readback-budget", "replay-determinism", "trace-purity"]

    def test_engine_ticks_are_discovered_roots(self):
        # guards the pass against silently losing its traversal targets
        from repro.lint import purity
        ctx = Context.for_root(REPO_ROOT)
        quals = {r.qual for r in purity._find_roots(ctx)}
        for expected in ("ServeEngine._make_tick_impl.tick",
                         "ServeEngine._make_tick_impl.spec_tick",
                         "ServeEngine._make_tick_impl.tree_tick",
                         "TrainEngine._build_tick.tick"):
            assert expected in quals, expected


# -- the violations the linter surfaced, fixed + locked -----------------------


def _accountant():
    from repro.core.accounting import AccountantConfig, CarbonAccountant
    return CarbonAccountant(AccountantConfig(device="tpu_v5e", n_devices=1,
                                             grid_mix="NY"))


class TestLintSurfacedAccountingFix:
    def test_chaos_exposure_channels_are_billed(self):
        # L401 found faults_injected/degraded/readback_retries unbilled
        from repro.serve.engine import StepMetrics

        acct = _accountant()
        m = StepMetrics(tokens=8, active_slots=2, wall_s=0.1,
                        faults_injected=3, degraded=1, readback_retries=2)
        acct.observe_serve(m)
        acct.observe_serve(m)
        rep = acct.report()
        assert rep["faults_injected"] == 6.0
        assert rep["degraded_ticks"] == 2.0
        assert rep["readback_retries"] == 4.0
        assert rep["degraded_tick_rate"] == pytest.approx(1.0)

    def test_chaos_exposure_channels_zero_guarded_and_snapshotted(self):
        acct = _accountant()
        rep = acct.report()     # no ticks observed: ratios must be 0.0
        assert rep["degraded_tick_rate"] == 0.0
        assert rep["recovery_j_per_fault"] == 0.0
        # and the new ledgers survive the snapshot round-trip
        state = acct.state_dict()
        for k in ("_faults_injected", "_degraded_ticks",
                  "_readback_retries"):
            assert k in state
        fresh = _accountant()
        fresh.load_state(state)
        assert fresh.report()["faults_injected"] == 0.0

    def test_exempt_lists_only_name_real_fields(self):
        import dataclasses
        from repro.serve import engine as se
        from repro.train import engine as te
        serve_fields = {f.name for f in dataclasses.fields(se.StepMetrics)}
        train_fields = {f.name
                        for f in dataclasses.fields(te.TrainStepMetrics)}
        assert se.ACCOUNTING_EXEMPT <= serve_fields
        assert te.TRAIN_ACCOUNTING_EXEMPT <= train_fields


# -- bench_util.required_keys (the smoke gates' shared schema check) ----------


class TestRequiredKeys:
    def _rk(self):
        sys.path.insert(0, os.path.join(REPO_ROOT, "benchmarks"))
        try:
            from bench_util import required_keys
        finally:
            sys.path.pop(0)
        return required_keys

    def test_present_keys_pass_and_chain(self):
        rk = self._rk()
        payload = {"speedup": 1.4, "paged": {"j_per_token": 0.2}}
        assert rk(payload, ("speedup", "paged.j_per_token")) is payload

    def test_missing_top_level_key_raises(self):
        rk = self._rk()
        with pytest.raises(AssertionError, match="speedup"):
            rk({"paged": {}}, ("speedup",), where="BENCH_x.json")

    def test_missing_nested_key_names_full_path(self):
        rk = self._rk()
        with pytest.raises(AssertionError, match=r"paged\.j_per_token"):
            rk({"paged": {"other": 1}}, ("paged.j_per_token",))

    def test_all_missing_paths_reported_in_one_error(self):
        rk = self._rk()
        with pytest.raises(AssertionError) as ei:
            rk({"a": 1}, ("b", "c.d", "a"))
        msg = str(ei.value)
        assert "b" in msg and "c.d" in msg

    def test_descent_through_non_dict_is_missing(self):
        rk = self._rk()
        with pytest.raises(AssertionError, match=r"a\.b"):
            rk({"a": 3}, ("a.b",))
