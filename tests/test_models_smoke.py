"""Per-architecture smoke tests (assignment deliverable f).

Every assigned arch instantiates its REDUCED config and runs one forward +
one train step on CPU, asserting output shapes and finiteness. The FULL
configs are exercised only via the dry-run (ShapeDtypeStruct, no allocation)
— tested here structurally via eval_shape param counts.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import base as cfgbase
from repro.models import cnn as cnn_lib
from repro.models import common
from repro.models import encdec as encdec_lib
from repro.models import transformer as tf_lib

LM_ARCHS = ["gemma3-27b", "starcoder2-7b", "granite-34b", "qwen1.5-110b",
            "moonshot-v1-16b-a3b", "kimi-k2-1t-a32b", "zamba2-7b",
            "qwen2-vl-72b", "mamba2-1.3b"]

# nominal (B) vs config-derived total params; moonshot's assigned config
# computes ~27B vs its 16B headline (configs/moonshot note)
PARAM_TOLERANCE = {"moonshot-v1-16b-a3b": 0.8}


def _lm_batch(cfg, b=2, s=16):
    key = jax.random.PRNGKey(9)
    batch = {"tokens": jax.random.randint(key, (b, s), 0, cfg.vocab),
             "labels": jax.random.randint(key, (b, s), 0, cfg.vocab)}
    if cfg.pos_emb == "mrope":
        pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        batch["positions"] = jnp.broadcast_to(pos[..., None], (b, s, 3))
    if cfg.vision_tokens > 0:
        batch["vision_embeds"] = jax.random.normal(
            key, (b, cfg.vision_tokens, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch_id", LM_ARCHS)
def test_lm_smoke_forward_and_train_step(arch_id):
    arch = cfgbase.get(arch_id)
    cfg = arch.make_smoke()
    ax = tf_lib.init_lm(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    batch = _lm_batch(cfg)
    logits, aux = tf_lib.forward(ax.params, cfg, batch["tokens"],
                                 positions=batch.get("positions"),
                                 vision_embeds=batch.get("vision_embeds"))
    assert logits.shape == (2, 16, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits).all()), arch_id

    loss, metrics = tf_lib.loss_fn(ax.params, cfg, batch)
    assert bool(jnp.isfinite(loss)), arch_id
    grads = jax.grad(lambda p: tf_lib.loss_fn(p, cfg, batch)[0])(ax.params)
    assert all(bool(jnp.isfinite(g).all()) for g in jax.tree.leaves(grads)), arch_id


@pytest.mark.parametrize("arch_id", ["gemma3-27b", "zamba2-7b", "mamba2-1.3b"])
def test_lm_smoke_decode(arch_id):
    """Prefill+decode equivalence for one arch per family (dense-window,
    hybrid, ssm)."""
    arch = cfgbase.get(arch_id)
    cfg = arch.make_smoke()
    ax = tf_lib.init_lm(jax.random.PRNGKey(1), cfg, dtype=jnp.float32)
    toks = jax.random.randint(jax.random.PRNGKey(2), (1, 12), 0, cfg.vocab)
    full, _ = tf_lib.forward(ax.params, cfg, toks)
    _, caches = tf_lib.prefill(ax.params, cfg, toks[:, :6], max_len=12,
                               cache_dtype=jnp.float32)
    last = None
    for t in range(6, 12):
        last, caches = tf_lib.decode_step(ax.params, cfg, toks[:, t:t + 1],
                                          jnp.asarray(t), caches)
    np.testing.assert_allclose(np.asarray(last[:, 0]),
                               np.asarray(full[:, -1, :cfg.vocab]), atol=1e-3)


def test_whisper_smoke():
    arch = cfgbase.get("whisper-large-v3")
    cfg = arch.make_smoke()
    ax = encdec_lib.init_encdec(jax.random.PRNGKey(3), cfg, dtype=jnp.float32)
    b = {"frames": jax.random.normal(jax.random.PRNGKey(4),
                                     (2, cfg.n_audio_ctx, cfg.d_model)),
         "tokens": jax.random.randint(jax.random.PRNGKey(5), (2, 8), 0, cfg.vocab),
         "labels": jax.random.randint(jax.random.PRNGKey(6), (2, 8), 0, cfg.vocab)}
    loss, _ = encdec_lib.loss_fn(ax.params, cfg, b)
    assert bool(jnp.isfinite(loss))
    g = jax.grad(lambda p: encdec_lib.loss_fn(p, cfg, b)[0])(ax.params)
    assert all(bool(jnp.isfinite(v).all()) for v in jax.tree.leaves(g))


@pytest.mark.parametrize("arch_id", ["alexnet", "vgg16"])
def test_cnn_smoke(arch_id):
    arch = cfgbase.get(arch_id)
    cfg = arch.make_smoke()
    ax = cnn_lib.init_cnn(jax.random.PRNGKey(7), cfg)
    imgs = jax.random.normal(jax.random.PRNGKey(8),
                             (2, cfg.image_size, cfg.image_size, 3))
    logits = cnn_lib.forward(ax.params, cfg, imgs)
    assert logits.shape == (2, cfg.num_classes)
    assert bool(jnp.isfinite(logits).all())
    loss, m = cnn_lib.loss_fn(ax.params, cfg,
                              {"images": imgs,
                               "labels": jnp.array([0, 1])},
                              rng=jax.random.PRNGKey(9))
    assert bool(jnp.isfinite(loss))


@pytest.mark.parametrize("arch_id", LM_ARCHS)
def test_full_config_param_count_matches_nominal(arch_id):
    """eval_shape the FULL config (no allocation) and check the param count
    lands near the architecture's headline size."""
    arch = cfgbase.get(arch_id)
    cfg = arch.make_config()
    ax = jax.eval_shape(lambda k: tf_lib.init_lm(k, cfg, dtype=jnp.bfloat16),
                        jax.random.PRNGKey(0))
    n = sum(float(np.prod(x.shape)) for x in jax.tree.leaves(ax.params))
    tol = PARAM_TOLERANCE.get(arch_id, 0.30)
    assert abs(n - arch.params_nominal) / arch.params_nominal <= tol, (
        arch_id, f"{n/1e9:.1f}B vs nominal {arch.params_nominal/1e9:.0f}B")


def test_whisper_full_param_count():
    arch = cfgbase.get("whisper-large-v3")
    cfg = arch.make_config()
    ax = jax.eval_shape(
        lambda k: encdec_lib.init_encdec(k, cfg, dtype=jnp.bfloat16),
        jax.random.PRNGKey(0))
    n = sum(float(np.prod(x.shape)) for x in jax.tree.leaves(ax.params))
    assert abs(n - 1.55e9) / 1.55e9 < 0.30, f"{n/1e9:.2f}B"


def test_registry_complete():
    ids = cfgbase.all_arch_ids()
    assert len(ids) == 12       # 10 assigned + alexnet + vgg16
    for arch_id in ids:
        spec = cfgbase.get(arch_id)
        assert spec.make_smoke() is not None


def test_long_context_skip_list():
    """DESIGN.md §8: long_500k only for sub-quadratic archs."""
    runs = {a for a in cfgbase.all_arch_ids(lm_only=True)
            if "long_500k" in cfgbase.get(a).shapes}
    assert runs == {"gemma3-27b", "zamba2-7b", "mamba2-1.3b"}
