"""MoE: dense reference == capacity dispatch; router properties."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.models import moe as moe_lib


def _setup(e=8, k=2, d=32, f=64, cf=8.0, seed=0):
    cfg = moe_lib.MoEConfig(d_model=d, d_ff=f, n_experts=e, top_k=k,
                            capacity_factor=cf)
    ax = moe_lib.init_moe(jax.random.PRNGKey(seed), cfg, dtype=jnp.float32)
    return cfg, ax.params


class TestRouting:
    def test_gates_sum_to_one(self):
        cfg, p = _setup()
        x = jax.random.normal(jax.random.PRNGKey(1), (24, 32))
        gates, ids, aux = moe_lib.route(p, cfg, x)
        np.testing.assert_allclose(np.asarray(gates.sum(-1)), 1.0, atol=1e-5)
        assert gates.shape == (24, 2) and ids.shape == (24, 2)

    def test_topk_ids_distinct(self):
        cfg, p = _setup()
        x = jax.random.normal(jax.random.PRNGKey(2), (16, 32))
        _, ids, _ = moe_lib.route(p, cfg, x)
        assert bool((ids[:, 0] != ids[:, 1]).all())

    def test_aux_loss_positive(self):
        cfg, p = _setup()
        x = jax.random.normal(jax.random.PRNGKey(3), (64, 32))
        _, _, aux = moe_lib.route(p, cfg, x)
        assert float(aux) > 0

    @given(st.integers(0, 1000))
    @settings(max_examples=10, deadline=None)
    def test_ids_in_range(self, seed):
        cfg, p = _setup(seed=1)
        x = jax.random.normal(jax.random.PRNGKey(seed), (8, 32))
        _, ids, _ = moe_lib.route(p, cfg, x)
        assert int(ids.min()) >= 0 and int(ids.max()) < cfg.n_experts


class TestCapacityPath:
    def test_matches_dense_with_ample_capacity(self):
        cfg, p = _setup(cf=8.0)
        x = jax.random.normal(jax.random.PRNGKey(4), (2, 16, 32))
        yd, auxd = moe_lib.moe_dense(p, cfg, x)
        yc, auxc = moe_lib.moe_capacity(p, cfg, x, group_size=16)
        np.testing.assert_allclose(np.asarray(yd), np.asarray(yc), atol=2e-5)
        assert float(auxd) == pytest.approx(float(auxc), rel=1e-5)

    def test_group_invariance(self):
        """Result must not depend on the group partition when capacity ample."""
        cfg, p = _setup(cf=16.0)
        x = jax.random.normal(jax.random.PRNGKey(5), (2, 32, 32))
        y1, _ = moe_lib.moe_capacity(p, cfg, x, group_size=16)
        y2, _ = moe_lib.moe_capacity(p, cfg, x, group_size=64)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-5)

    def test_drops_under_tight_capacity(self):
        """With capacity_factor << 1 some routes drop: outputs shrink, stay
        finite (dropless-ness bounded by cf — the documented semantic)."""
        cfg, p = _setup(cf=0.25)
        x = jax.random.normal(jax.random.PRNGKey(6), (2, 32, 32))
        y, _ = moe_lib.moe_capacity(p, cfg, x, group_size=32)
        yd, _ = moe_lib.moe_dense(p, cfg, x)
        assert bool(jnp.isfinite(y).all())
        assert float(jnp.linalg.norm(y)) < float(jnp.linalg.norm(yd)) + 1e-3

    def test_grads_flow(self):
        cfg, p = _setup()
        x = jax.random.normal(jax.random.PRNGKey(7), (1, 16, 32))

        def loss(p):
            y, aux = moe_lib.moe_capacity(p, cfg, x, group_size=16)
            return jnp.sum(y ** 2) + aux

        g = jax.grad(loss)(p)
        for name in ("w_in", "w_gate", "w_out", "router"):
            assert float(jnp.abs(g[name]).max()) > 0, name


class TestDispatchSort:
    def test_counting_sort_fifo(self):
        ids = jnp.array([[0], [1], [0], [0], [1]], jnp.int32)
        slot_token, slot_of_route = moe_lib._counting_sort_dispatch(ids, 2, 2)
        # expert 0 gets tokens 0,2 (FIFO); token 3 dropped; expert 1: 1,4
        assert slot_token[0] == 0 and slot_token[1] == 2
        assert slot_token[2] == 1 and slot_token[3] == 4
        assert int(slot_of_route[3, 0]) == -1     # dropped
