"""shard_map expert-parallel MoE == dense reference (multi-device)."""

from tests._mp import run_multidevice


def test_moe_ep_matches_dense():
    out = run_multidevice("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.models import moe as moe_lib
from repro.parallel.compat import make_mesh, shard_map, axis_size
mesh = make_mesh((2, 4), ("data", "model"))
cfg = moe_lib.MoEConfig(d_model=32, d_ff=64, n_experts=8, top_k=2,
                        capacity_factor=8.0)
ax = moe_lib.init_moe(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 32))
y_ref, aux_ref = moe_lib.moe_dense(ax.params, cfg, x)

def ep(params, x):
    return moe_lib.moe_ep(params, cfg, x, "model",
                          axis_size("model"))[0]

param_specs = {"router": P(), "w_in": P("model"), "w_gate": P("model"),
               "w_out": P("model")}
f = jax.jit(shard_map(ep, mesh=mesh,
                          in_specs=(param_specs, P("data", None, None)),
                          out_specs=P("data", None, None)))
y_ep = f(ax.params, x)
err = float(jnp.abs(y_ref - y_ep).max())
print("ERR", err)
assert err < 2e-4, err
print("OK")
""", n_devices=8)
    assert "OK" in out
