"""AdamW (incl. quantized states) + schedules."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import (AdamWConfig, apply_updates, clip_by_global_norm,
                         global_norm, init_opt_state, schedules)


def _quadratic_problem(seed=0, n=32):
    key = jax.random.PRNGKey(seed)
    target = jax.random.normal(key, (n,))
    params = {"w": jnp.zeros((n,))}

    def loss(p):
        return jnp.sum((p["w"] - target) ** 2)

    return params, loss, target


class TestAdamW:
    def test_first_step_matches_reference(self):
        """After one step from zero moments, update = lr * sign-ish formula."""
        cfg = AdamWConfig(lr=0.1, b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.0,
                          grad_clip=0.0)
        params = {"w": jnp.array([1.0, -2.0])}
        grads = {"w": jnp.array([0.5, -0.5])}
        state = init_opt_state(params, cfg)
        new_p, new_s, m = apply_updates(params, grads, state, cfg)
        # bias-corrected mhat = g, vhat = g^2 -> update = lr * g/|g| = lr*sign
        np.testing.assert_allclose(
            np.asarray(new_p["w"]),
            np.asarray(params["w"]) - 0.1 * np.sign([0.5, -0.5]), atol=1e-5)

    @pytest.mark.parametrize("state_dtype", ["fp32", "bf16", "int8"])
    def test_converges_on_quadratic(self, state_dtype):
        cfg = AdamWConfig(lr=0.05, weight_decay=0.0, grad_clip=0.0,
                          state_dtype=state_dtype)
        params, loss, target = _quadratic_problem()
        state = init_opt_state(params, cfg)
        step = jax.jit(lambda p, s: apply_updates(p, jax.grad(loss)(p), s, cfg))
        for _ in range(400):
            params, state, _ = step(params, state)
        final = float(loss(params))
        assert final < 0.05, (state_dtype, final)

    def test_weight_decay_shrinks(self):
        cfg = AdamWConfig(lr=0.1, weight_decay=0.5, grad_clip=0.0)
        params = {"w": jnp.ones((4,)) * 10}
        grads = {"w": jnp.zeros((4,))}
        state = init_opt_state(params, cfg)
        new_p, _, _ = apply_updates(params, grads, state, cfg)
        assert float(new_p["w"][0]) < 10.0

    def test_grad_clip(self):
        g = {"a": jnp.ones((100,)) * 10}
        clipped, norm = clip_by_global_norm(g, 1.0)
        assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)
        assert float(norm) == pytest.approx(100.0, rel=1e-5)

    def test_master_kept_for_bf16_params(self):
        cfg = AdamWConfig(use_master=True)
        params = {"w": jnp.ones((4,), jnp.bfloat16)}
        state = init_opt_state(params, cfg)
        assert "master" in state
        assert state["master"]["w"].dtype == jnp.float32

    def test_schedule_callable_lr(self):
        cfg = AdamWConfig(lr=schedules.warmup_cosine(1.0, 10, 100))
        assert float(cfg.lr_at(jnp.asarray(5))) == pytest.approx(0.5)
        assert float(cfg.lr_at(jnp.asarray(100))) == pytest.approx(0.0, abs=1e-6)


class TestSchedules:
    def test_warmup_cosine_shape(self):
        fn = schedules.warmup_cosine(2.0, 10, 110, floor=0.2)
        assert float(fn(jnp.asarray(0))) == pytest.approx(0.0)
        assert float(fn(jnp.asarray(10))) == pytest.approx(2.0)
        assert float(fn(jnp.asarray(110))) == pytest.approx(0.2)

    def test_rsqrt_decay(self):
        fn = schedules.warmup_rsqrt(1.0, 100)
        assert float(fn(jnp.asarray(100))) == pytest.approx(1.0)
        assert float(fn(jnp.asarray(400))) == pytest.approx(0.5)

    def test_linear_decay(self):
        fn = schedules.linear_decay(1.0, 0, 100)
        assert float(fn(jnp.asarray(50))) == pytest.approx(0.5)
