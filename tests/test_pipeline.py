"""GPipe pipeline parallelism: pipelined == sequential (multi-device)."""

from tests._mp import run_multidevice


def test_pipeline_matches_sequential():
    out = run_multidevice("""
import jax, jax.numpy as jnp, numpy as np
from repro.parallel import pipeline as pp
from repro.parallel.compat import make_mesh
mesh = make_mesh((4,), ("pipe",))
key = jax.random.PRNGKey(0)
n_stage, d, batch, micro = 4, 16, 8, 4
ws = jax.random.normal(key, (n_stage, d, d)) * 0.3

def stage_fn(w, x):
    return jnp.tanh(x @ w[0] if w.ndim == 3 else x @ w)

# stage params carry a leading per-rank dim of 1 inside shard_map
def stage(wslice, x):
    return jnp.tanh(x @ wslice)

runner = pp.make_pipelined_fn(stage, mesh, n_micro=micro)
x = jax.random.normal(jax.random.fold_in(key, 1), (batch, d))
y_pipe = runner(ws, x)
y_seq = x
for i in range(n_stage):
    y_seq = jnp.tanh(y_seq @ ws[i])
err = float(jnp.abs(y_pipe - y_seq).max())
print("ERR", err)
assert err < 1e-5, err
# differentiability through the pipeline
def loss(ws):
    return jnp.sum(runner(ws, x) ** 2)
g = jax.grad(loss)(ws)
assert all(bool(jnp.isfinite(v).all()) for v in jax.tree.leaves(g))
assert float(jnp.abs(g).max()) > 0
print("OK")
""", n_devices=4)
    assert "OK" in out
