"""Ternary/binary/int8 quantization properties (paper C5)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.quant import int8, ternary


class TestTernary:
    def test_codebook(self):
        w = jax.random.normal(jax.random.PRNGKey(0), (64, 32))
        tw = ternary.ternarize(w)
        vals = set(np.unique(np.asarray(tw.q)))
        assert vals <= {-1, 0, 1}

    def test_sign_agreement(self):
        """Nonzero codes carry the sign of the original weight."""
        w = jax.random.normal(jax.random.PRNGKey(1), (128, 16))
        tw = ternary.ternarize(w)
        q = np.asarray(tw.q, np.float32)
        wn = np.asarray(w)
        nz = q != 0
        assert (np.sign(wn[nz]) == q[nz]).all()

    def test_reconstruction_error_bounded(self):
        """TWN on gaussian weights: relative L2 error ~0.4-0.6."""
        w = jax.random.normal(jax.random.PRNGKey(2), (512, 256))
        err = ternary.quant_error(w, ternary.ternarize(w))
        assert 0.25 < err < 0.7

    def test_better_than_binary_on_sparse(self):
        key = jax.random.PRNGKey(3)
        w = jax.random.normal(key, (256, 64))
        mask = jax.random.bernoulli(jax.random.fold_in(key, 1), 0.5, w.shape)
        w = w * mask    # half zeros: ternary should model it better
        e_t = ternary.quant_error(w, ternary.ternarize(w))
        e_b = ternary.quant_error(w, ternary.binarize(w))
        assert e_t < e_b

    def test_bitplane_roundtrip(self):
        w = jax.random.normal(jax.random.PRNGKey(4), (64, 32))
        tw = ternary.ternarize(w)
        plus, minus = ternary.to_bitplanes(tw)
        assert not bool(jnp.any((plus == 1) & (minus == 1)))
        back = ternary.from_bitplanes(plus, minus, tw.scale)
        np.testing.assert_array_equal(np.asarray(back.q), np.asarray(tw.q))

    @given(st.floats(0.1, 1.5))
    @settings(max_examples=15, deadline=None)
    def test_threshold_monotone_sparsity(self, thr):
        w = jax.random.normal(jax.random.PRNGKey(5), (256, 32))
        z1 = float(jnp.mean(ternary.ternarize(w, thr).q == 0))
        z2 = float(jnp.mean(ternary.ternarize(w, thr + 0.3).q == 0))
        assert z2 >= z1 - 1e-6

    def test_tree_quantization_skips_embed(self):
        params = {"embed": {"w": jnp.ones((8, 4))},
                  "mlp": {"w_in": jnp.ones((4, 8)), "b": jnp.ones((8,))}}
        qt = ternary.quantize_tree(params)
        assert isinstance(qt["mlp"]["w_in"], ternary.TernaryWeight)
        assert not isinstance(qt["embed"]["w"], ternary.TernaryWeight)
        assert not isinstance(qt["mlp"]["b"], ternary.TernaryWeight)
        de = ternary.dequantize_tree(qt)
        assert de["mlp"]["w_in"].shape == (4, 8)


class TestInt8:
    def test_roundtrip_error_small(self):
        w = jax.random.normal(jax.random.PRNGKey(6), (256, 128))
        err = int8.quant_error(w, int8.quantize(w))
        assert err < 0.01

    def test_range_respected(self):
        w = jax.random.normal(jax.random.PRNGKey(7), (64, 64)) * 100
        iw = int8.quantize(w)
        assert int(jnp.abs(iw.q).max()) <= 127

    def test_stochastic_rounding_unbiased(self):
        w = jnp.full((1, 4096), 0.3)
        outs = []
        for i in range(32):
            iw = int8.quantize_stochastic(w, jax.random.PRNGKey(i))
            outs.append(float(int8.dequantize(iw).mean()))
        assert np.mean(outs) == pytest.approx(0.3, rel=0.01)

    def test_inference_accuracy_preserved_on_cnn(self):
        """Ternary AlexNet-smoke logits correlate with fp32 logits (the
        paper's claim that ternary reduction keeps reasonable accuracy)."""
        from repro.configs import base as cfgbase
        from repro.models import cnn as cnn_lib
        from repro.kernels import ops as kops
        arch = cfgbase.get("alexnet")
        cfg = arch.make_smoke()
        ax = cnn_lib.init_cnn(jax.random.PRNGKey(8), cfg)
        imgs = jax.random.normal(jax.random.PRNGKey(9), (4, 32, 32, 3))
        base = cnn_lib.forward(ax.params, cfg, imgs)
        qp = ternary.quantize_tree(
            ax.params, predicate=lambda n, x: x.ndim == 2 and "fc" in n)
        deq = ternary.dequantize_tree(qp)
        quant = cnn_lib.forward(deq, cfg, imgs)
        corr = np.corrcoef(np.asarray(base).ravel(),
                           np.asarray(quant).ravel())[0, 1]
        # random-init logit correlation is seed/backend sensitive (measured
        # 0.72-0.78 across XLA versions); 0.7 keeps the qualitative claim
        assert corr > 0.7, corr
