"""Ternary/binary/int8 quantization properties (paper C5)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.quant import int8, ternary


class TestTernary:
    def test_codebook(self):
        w = jax.random.normal(jax.random.PRNGKey(0), (64, 32))
        tw = ternary.ternarize(w)
        vals = set(np.unique(np.asarray(tw.q)))
        assert vals <= {-1, 0, 1}

    def test_sign_agreement(self):
        """Nonzero codes carry the sign of the original weight."""
        w = jax.random.normal(jax.random.PRNGKey(1), (128, 16))
        tw = ternary.ternarize(w)
        q = np.asarray(tw.q, np.float32)
        wn = np.asarray(w)
        nz = q != 0
        assert (np.sign(wn[nz]) == q[nz]).all()

    def test_reconstruction_error_bounded(self):
        """TWN on gaussian weights: relative L2 error ~0.4-0.6."""
        w = jax.random.normal(jax.random.PRNGKey(2), (512, 256))
        err = ternary.quant_error(w, ternary.ternarize(w))
        assert 0.25 < err < 0.7

    def test_better_than_binary_on_sparse(self):
        key = jax.random.PRNGKey(3)
        w = jax.random.normal(key, (256, 64))
        mask = jax.random.bernoulli(jax.random.fold_in(key, 1), 0.5, w.shape)
        w = w * mask    # half zeros: ternary should model it better
        e_t = ternary.quant_error(w, ternary.ternarize(w))
        e_b = ternary.quant_error(w, ternary.binarize(w))
        assert e_t < e_b

    def test_bitplane_roundtrip(self):
        w = jax.random.normal(jax.random.PRNGKey(4), (64, 32))
        tw = ternary.ternarize(w)
        plus, minus = ternary.to_bitplanes(tw)
        assert not bool(jnp.any((plus == 1) & (minus == 1)))
        back = ternary.from_bitplanes(plus, minus, tw.scale)
        np.testing.assert_array_equal(np.asarray(back.q), np.asarray(tw.q))

    @given(st.floats(0.1, 1.5))
    @settings(max_examples=15, deadline=None)
    def test_threshold_monotone_sparsity(self, thr):
        w = jax.random.normal(jax.random.PRNGKey(5), (256, 32))
        z1 = float(jnp.mean(ternary.ternarize(w, thr).q == 0))
        z2 = float(jnp.mean(ternary.ternarize(w, thr + 0.3).q == 0))
        assert z2 >= z1 - 1e-6

    def test_tree_quantization_skips_embed(self):
        params = {"embed": {"w": jnp.ones((8, 4))},
                  "mlp": {"w_in": jnp.ones((4, 8)), "b": jnp.ones((8,))}}
        qt = ternary.quantize_tree(params)
        assert isinstance(qt["mlp"]["w_in"], ternary.TernaryWeight)
        assert not isinstance(qt["embed"]["w"], ternary.TernaryWeight)
        assert not isinstance(qt["mlp"]["b"], ternary.TernaryWeight)
        de = ternary.dequantize_tree(qt)
        assert de["mlp"]["w_in"].shape == (4, 8)


class TestInt8:
    def test_roundtrip_error_small(self):
        w = jax.random.normal(jax.random.PRNGKey(6), (256, 128))
        err = int8.quant_error(w, int8.quantize(w))
        assert err < 0.01

    def test_range_respected(self):
        w = jax.random.normal(jax.random.PRNGKey(7), (64, 64)) * 100
        iw = int8.quantize(w)
        assert int(jnp.abs(iw.q).max()) <= 127

    def test_stochastic_rounding_unbiased(self):
        w = jnp.full((1, 4096), 0.3)
        outs = []
        for i in range(32):
            iw = int8.quantize_stochastic(w, jax.random.PRNGKey(i))
            outs.append(float(int8.dequantize(iw).mean()))
        assert np.mean(outs) == pytest.approx(0.3, rel=0.01)

    def test_roundtrip_error_elementwise_bound(self):
        """Symmetric rounding guarantees |x - deq(x)| <= scale/2 per
        element (scale = amax/127 per channel)."""
        w = jax.random.normal(jax.random.PRNGKey(20), (128, 64)) * 3.0
        iw = int8.quantize(w)
        err = jnp.abs(w - int8.dequantize(iw))
        assert bool(jnp.all(err <= iw.scale / 2 + 1e-7))

    def test_all_zero_channel_scale_floor(self):
        """An all-zero channel gets the positive floor scale: dequant is
        exactly zero, nothing divides by zero, nothing goes NaN."""
        w = jnp.zeros((16, 8)).at[:, 0].set(1.0)
        iw = int8.quantize(w, axis=0)
        assert bool(jnp.all(iw.scale > 0))
        back = int8.dequantize(iw)
        assert bool(jnp.all(jnp.isfinite(back)))
        np.testing.assert_array_equal(np.asarray(back[:, 1:]), 0.0)
        q, s = int8.quantize_rowwise(jnp.zeros((4, 8)))
        assert bool(jnp.all(s > 0)) and not bool(jnp.any(q))

    def test_stochastic_rounding_unbiased_many_draws(self):
        """Mean over many independent draws converges to the true value for
        a point exactly between two codes (the worst case for bias)."""
        val = 0.15
        w = jnp.full((1, 512), val)
        keys = jax.random.split(jax.random.PRNGKey(21), 256)
        deq = jax.vmap(lambda k: int8.dequantize(
            int8.quantize_stochastic(w, k)))(keys)
        assert float(deq.mean()) == pytest.approx(val, rel=0.005)

    def test_int8weight_pytree_through_jit(self):
        """Int8Weight is a registered pytree: it crosses jit boundaries as
        an argument AND a return value without flattening surprises."""
        w = jax.random.normal(jax.random.PRNGKey(22), (32, 16))
        iw = int8.quantize(w)
        leaves, treedef = jax.tree.flatten(iw)
        assert len(leaves) == 2
        assert isinstance(jax.tree.unflatten(treedef, leaves),
                          int8.Int8Weight)

        @jax.jit
        def roundtrip(iw_in):
            return int8.Int8Weight(q=iw_in.q, scale=iw_in.scale * 2.0)

        out = roundtrip(iw)
        assert isinstance(out, int8.Int8Weight)
        np.testing.assert_array_equal(np.asarray(out.q), np.asarray(iw.q))
        np.testing.assert_allclose(np.asarray(out.scale),
                                   np.asarray(iw.scale) * 2.0)

    def test_quantize_weight_channelwise_scales(self):
        """quantize_weight keeps one scale per output channel (keepdims) so
        badly-scaled channels don't poison each other."""
        w = jax.random.normal(jax.random.PRNGKey(23), (64, 8))
        w = w * (10.0 ** jnp.arange(8))        # 8 orders of magnitude
        qd = int8.quantize_weight(w)
        assert qd["s8"].shape == (1, 8)
        back = qd["q8"].astype(jnp.float32) * qd["s8"]

        def per_channel_rel(a):
            return jnp.linalg.norm(a - w, axis=0) / jnp.linalg.norm(w, axis=0)

        assert float(per_channel_rel(back).max()) < 0.01
        # per-tensor quantization rounds the small channels to zero
        amax = float(jnp.abs(w).max())
        coarse = jnp.round(w / (amax / 127)) * (amax / 127)
        assert float(per_channel_rel(coarse).max()) > 0.5

    def test_rowwise_roundtrip_bound(self):
        x = jax.random.normal(jax.random.PRNGKey(24), (4, 6, 2, 16))
        q, s = int8.quantize_rowwise(x)
        assert q.dtype == jnp.int8 and s.shape == (4, 6, 2)
        back = int8.dequantize_rowwise(q, s)
        assert bool(jnp.all(jnp.abs(back - x) <= s[..., None] / 2 + 1e-7))

    def test_inference_accuracy_preserved_on_cnn(self):
        """Ternary AlexNet-smoke logits correlate with fp32 logits (the
        paper's claim that ternary reduction keeps reasonable accuracy)."""
        from repro.configs import base as cfgbase
        from repro.models import cnn as cnn_lib
        from repro.kernels import ops as kops
        arch = cfgbase.get("alexnet")
        cfg = arch.make_smoke()
        ax = cnn_lib.init_cnn(jax.random.PRNGKey(8), cfg)
        imgs = jax.random.normal(jax.random.PRNGKey(9), (4, 32, 32, 3))
        base = cnn_lib.forward(ax.params, cfg, imgs)
        qp = ternary.quantize_tree(
            ax.params, predicate=lambda n, x: x.ndim == 2 and "fc" in n)
        deq = ternary.dequantize_tree(qp)
        quant = cnn_lib.forward(deq, cfg, imgs)
        corr = np.corrcoef(np.asarray(base).ravel(),
                           np.asarray(quant).ravel())[0, 1]
        # random-init logit correlation is seed/backend sensitive (measured
        # 0.72-0.78 across XLA versions); 0.7 keeps the qualitative claim
        assert corr > 0.7, corr
