"""Int8-weight serving mode (§Perf HC-C iter 3, the paper's C5 in the LM)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import flops as fl
from repro.models import layers, transformer as tf
from repro.quant.int8 import quantize_params_for_serving


def _tiny():
    cfg = tf.LMConfig(name="t", d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                      vocab=97, pattern=(tf.BlockSpec(),), repeats=2,
                      remat="none")
    ax = tf.init_lm(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    return cfg, ax


class TestWeightLoader:
    def test_wl_passthrough(self):
        w = jnp.ones((4, 4), jnp.float32)
        assert layers.wl(w, jnp.bfloat16).dtype == jnp.bfloat16

    def test_wl_dequant(self):
        w = jax.random.normal(jax.random.PRNGKey(1), (64, 32))
        q8, _ = quantize_params_for_serving({"wq": w}, {"wq": ("embed", "ffn")})
        back = layers.wl(q8["wq"], jnp.float32)
        rel = float(jnp.linalg.norm(back - w) / jnp.linalg.norm(w))
        assert rel < 0.01

    def test_stacked_per_layer_scales(self):
        w = jax.random.normal(jax.random.PRNGKey(2), (3, 16, 8))
        w = w * jnp.array([1.0, 10.0, 100.0])[:, None, None]
        q8, ax = quantize_params_for_serving(
            {"w_in": w}, {"w_in": ("stack", "embed", "ffn")})
        assert q8["w_in"]["s8"].shape == (3,)
        back = q8["w_in"]["q8"].astype(jnp.float32) \
            * q8["w_in"]["s8"][:, None, None]
        rel = float(jnp.linalg.norm(back - w) / jnp.linalg.norm(w))
        assert rel < 0.01
        assert ax["w_in"]["s8"] == ("stack",)


class TestServedModel:
    def test_forward_close_to_fp32(self):
        cfg, ax = _tiny()
        q8, _ = quantize_params_for_serving(ax.params, ax.axes)
        toks = jax.random.randint(jax.random.PRNGKey(3), (2, 10), 0, 97)
        lg, _ = tf.forward(ax.params, cfg, toks)
        lg8, _ = tf.forward(q8, cfg, toks)
        corr = np.corrcoef(np.asarray(lg).ravel(), np.asarray(lg8).ravel())[0, 1]
        assert corr > 0.995, corr

    def test_decode_runs_and_matches_forward(self):
        cfg, ax = _tiny()
        q8, _ = quantize_params_for_serving(ax.params, ax.axes)
        toks = jax.random.randint(jax.random.PRNGKey(4), (1, 8), 0, 97)
        full, _ = tf.forward(q8, cfg, toks)
        _, cc = tf.prefill(q8, cfg, toks[:, :4], max_len=8,
                           cache_dtype=jnp.float32)
        last = None
        for t in range(4, 8):
            last, cc = tf.decode_step(q8, cfg, toks[:, t:t + 1],
                                      jnp.asarray(t), cc)
        np.testing.assert_allclose(np.asarray(last[:, 0]),
                                   np.asarray(full[:, -1, :97]), atol=1e-3)

    def test_embed_and_norms_not_quantized(self):
        cfg, ax = _tiny()
        q8, _ = quantize_params_for_serving(ax.params, ax.axes)
        assert not isinstance(q8["embed"]["w"], dict)
        assert not isinstance(q8["final_norm"]["scale"], dict)
        assert isinstance(q8["pat0"]["attn"]["wq"], dict)


class TestNarrowTrafficBilling:
    def test_int8_operand_billed_narrow(self):
        def f(w, x):
            deq = w["q8"].astype(jnp.bfloat16) * w["s8"].astype(jnp.bfloat16)
            return x @ deq
        wq = {"q8": jax.ShapeDtypeStruct((256, 128), jnp.int8),
              "s8": jax.ShapeDtypeStruct((), jnp.float32)}
        xs = jax.ShapeDtypeStruct((8, 256), jnp.bfloat16)
        c = fl.cost_of_fn(f, wq, xs)
        expected = 8 * 256 * 2 + 256 * 128 * 1 + 8 * 128 * 2
        assert c["traffic_bytes_global"] == pytest.approx(expected)

    def test_bf16_operand_billed_full(self):
        def f(w, x):
            return x @ w
        ws = jax.ShapeDtypeStruct((256, 128), jnp.bfloat16)
        xs = jax.ShapeDtypeStruct((8, 256), jnp.bfloat16)
        c = fl.cost_of_fn(f, ws, xs)
        expected = (8 * 256 + 256 * 128 + 8 * 128) * 2
        assert c["traffic_bytes_global"] == pytest.approx(expected)
