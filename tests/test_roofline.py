"""Collective parser (incl. while-loop trip counts) + roofline terms."""

import pytest

from repro.core import roofline as rl

SIMPLE_HLO = """
HloModule test

ENTRY %main.1 (p0: f32[1024,1024]) -> f32[1024,1024] {
  %p0 = f32[1024,1024]{1,0} parameter(0)
  %ar = f32[1024,1024]{1,0} all-reduce(%p0), replica_groups={}, to_apply=%add.1
  %ag = f32[2048,1024]{1,0} all-gather(%ar), replica_groups={}
  ROOT %out = f32[1024,1024]{1,0} slice(%ag)
}
"""

LOOPED_HLO = """
HloModule looped

%cond.1 (arg: (s32[], f32[64,64])) -> pred[] {
  %arg = (s32[], f32[64,64]) parameter(0)
  %i = s32[] get-tuple-element(%arg), index=0
  %c = s32[] constant(12)
  ROOT %lt = pred[] compare(%i, %c), direction=LT
}

%body.1 (arg: (s32[], f32[64,64])) -> (s32[], f32[64,64]) {
  %arg = (s32[], f32[64,64]) parameter(0)
  %x = f32[64,64]{1,0} get-tuple-element(%arg), index=1
  %ar2 = f32[64,64]{1,0} all-reduce(%x), replica_groups={}, to_apply=%add.2
  %i2 = s32[] get-tuple-element(%arg), index=0
  ROOT %t = (s32[], f32[64,64]) tuple(%i2, %ar2)
}

ENTRY %main.2 (p: f32[64,64]) -> f32[64,64] {
  %p = f32[64,64]{1,0} parameter(0)
  %init = (s32[], f32[64,64]) tuple(s32[] constant(0), %p)
  %w = (s32[], f32[64,64]) while(%init), condition=%cond.1, body=%body.1
  ROOT %r = f32[64,64]{1,0} get-tuple-element(%w), index=1
}
"""


class TestCollectiveParser:
    def test_simple_counts_and_bytes(self):
        stats = rl.parse_collectives(SIMPLE_HLO)
        # all-reduce: 2 x 4 MB; all-gather: max(out 8MB, in 4MB) = 8 MB
        assert stats.bytes_by_op["all-reduce"] == pytest.approx(2 * 4 * 1024**2)
        assert stats.bytes_by_op["all-gather"] == pytest.approx(8 * 1024**2)
        assert stats.count_by_op == {"all-reduce": 1, "all-gather": 1}

    def test_while_body_multiplied_by_trip_count(self):
        stats = rl.parse_collectives(LOOPED_HLO)
        # 64*64*4 = 16384 B; all-reduce x2; x12 trips
        assert stats.bytes_by_op["all-reduce"] == pytest.approx(
            2 * 16384 * 12)

    def test_no_collectives(self):
        stats = rl.parse_collectives("ENTRY %m (p: f32[4]) -> f32[4] {\n}")
        assert stats.total_bytes == 0

    def test_shape_bytes_dtypes(self):
        assert rl._shape_bytes("bf16[2,3]") == 12
        assert rl._shape_bytes("f32[10] s8[4]") == 44
        assert rl._shape_bytes("pred[8]") == 8


class TestTerms:
    def test_term_formulas(self):
        t = rl.RooflineTerms(flops_per_device=197e12, bytes_per_device=819e9,
                             collective_bytes_per_device=50e9, n_devices=4)
        assert t.compute_s == pytest.approx(1.0)
        assert t.memory_s == pytest.approx(1.0)
        assert t.collective_s == pytest.approx(1.0)

    def test_useful_flops_ratio(self):
        t = rl.RooflineTerms(1e12, 1e9, 0.0, n_devices=8)
        assert t.useful_flops_ratio(4e12) == pytest.approx(0.5)

    def test_model_flops_helpers(self):
        assert rl.model_flops_train(1e9, 1e6) == pytest.approx(6e15)
        assert rl.model_flops_infer(1e9, 1e6) == pytest.approx(2e15)

    def test_real_compile_roundtrip(self):
        """End-to-end: tiny jit -> compiled -> terms (single device)."""
        import jax, jax.numpy as jnp
        f = jax.jit(lambda a, b: jnp.tanh(a @ b).sum())
        sds = jax.ShapeDtypeStruct((64, 64), jnp.float32)
        compiled = f.lower(sds, sds).compile()
        t = rl.from_compiled(compiled, n_devices=1, label="tiny")
        assert t.flops_per_device > 2 * 64**3 * 0.9
        assert t.collective_bytes_per_device == 0.0
        assert t.bound in ("compute", "memory")
