"""Serving engine: correctness vs. reference decode + continuous batching."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import transformer as tf_lib
from repro.serve import Request, ServeConfig, ServeEngine


def _engine(max_slots=3, max_len=64, vocab=61, seed=0):
    cfg = tf_lib.LMConfig(name="t", d_model=48, n_heads=4, n_kv_heads=2,
                          d_ff=96, vocab=vocab, pattern=(tf_lib.BlockSpec(),),
                          repeats=2, remat="none", vocab_pad_multiple=1)
    params = tf_lib.init_lm(jax.random.PRNGKey(seed), cfg,
                            dtype=jnp.float32).params
    eng = ServeEngine(params, cfg, ServeConfig(max_slots=max_slots,
                                               max_len=max_len,
                                               cache_dtype=jnp.float32))
    return eng, cfg, params


def _reference_greedy(params, cfg, prompt, n):
    lp, cc = tf_lib.prefill(params, cfg, jnp.asarray(prompt[None]),
                            max_len=64, cache_dtype=jnp.float32)
    out = [int(jnp.argmax(lp[0, -1]))]
    pos = len(prompt)
    for _ in range(n - 1):
        lg, cc = tf_lib.decode_step(params, cfg, jnp.asarray([[out[-1]]]),
                                    jnp.asarray(pos), cc)
        out.append(int(jnp.argmax(lg[0, 0])))
        pos += 1
    return out


class TestCorrectness:
    def test_single_request_matches_reference(self):
        eng, cfg, params = _engine()
        prompt = np.arange(5)
        eng.submit(prompt, max_tokens=5)
        r = eng.run_until_drained()[0]
        assert r.generated == _reference_greedy(params, cfg, prompt, 5)

    def test_batched_requests_each_match_reference(self):
        """Continuous batching must not cross-contaminate slots."""
        eng, cfg, params = _engine(max_slots=2)
        prompts = [np.arange(4), np.arange(3) + 7, np.arange(6) + 2]
        for p in prompts:
            eng.submit(p, max_tokens=4)
        done = sorted(eng.run_until_drained(), key=lambda r: r.uid)
        for r, p in zip(done, prompts):
            assert r.generated == _reference_greedy(params, cfg, p, 4), r.uid


class TestScheduling:
    def test_queue_drains_with_fewer_slots(self):
        eng, _, _ = _engine(max_slots=2)
        for i in range(6):
            eng.submit(np.arange(3) + i, max_tokens=3)
        done = eng.run_until_drained()
        assert len(done) == 6
        assert all(len(r.generated) == 3 for r in done)

    def test_slots_freed_and_reused(self):
        eng, _, _ = _engine(max_slots=1)
        eng.submit(np.arange(3), max_tokens=2)
        eng.submit(np.arange(3) + 1, max_tokens=2)
        done = eng.run_until_drained()
        assert [r.uid for r in done] == [1, 2]

    def test_max_len_respected(self):
        eng, _, _ = _engine(max_slots=1, max_len=12)
        eng.submit(np.arange(8), max_tokens=100)
        r = eng.run_until_drained()[0]
        assert len(r.prompt) + len(r.generated) <= 12

    def test_accountant_observes_ticks(self):
        from repro.core import accounting
        acct = accounting.CarbonAccountant(accounting.AccountantConfig(
            device="tpu_v5e", n_devices=1, grid_mix="NY"))
        eng, cfg, params = _engine()
        eng.accountant = acct
        eng.submit(np.arange(4), max_tokens=3)
        eng.run_until_drained()
        rep = acct.report()
        # prefill emits the first token; 3 tokens => >= 2 decode ticks
        assert rep["steps"] >= 2 and rep["operational_j"] > 0
