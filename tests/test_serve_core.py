"""Serve core: fused engine vs. reference loop, scheduling, determinism,
device residency, and the Pallas decode kernel."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import transformer as tf_lib
from repro.serve import (ReferenceEngine, Request, Scheduler, SchedulerConfig,
                         ServeConfig, ServeEngine)


def _cfg(vocab=61):
    return tf_lib.LMConfig(name="t", d_model=48, n_heads=4, n_kv_heads=2,
                           d_ff=96, vocab=vocab, pattern=(tf_lib.BlockSpec(),),
                           repeats=2, remat="none", vocab_pad_multiple=1)


def _params(cfg, seed=0):
    return tf_lib.init_lm(jax.random.PRNGKey(seed), cfg,
                          dtype=jnp.float32).params


def _engine(params, cfg, max_slots=3, max_len=64, **kw):
    return ServeEngine(params, cfg, ServeConfig(max_slots=max_slots,
                                                max_len=max_len, **kw))


def _reference_greedy(params, cfg, prompt, n, max_len=64):
    """Sequential single-sequence decode — the correctness oracle."""
    lp, cc = tf_lib.prefill(params, cfg, jnp.asarray(prompt[None]),
                            max_len=max_len, cache_dtype=jnp.float32)
    out = [int(jnp.argmax(lp[0, -1]))]
    pos = len(prompt)
    for _ in range(n - 1):
        lg, cc = tf_lib.decode_step(params, cfg, jnp.asarray([[out[-1]]]),
                                    jnp.asarray(pos), cc)
        out.append(int(jnp.argmax(lg[0, 0])))
        pos += 1
    return out


class TestGreedyIdentity:
    def test_mixed_lengths_match_sequential_reference(self):
        """Padded batched prefill + fused tick == sequential decode,
        token-for-token, across ragged prompt lengths."""
        cfg = _cfg()
        params = _params(cfg)
        eng = _engine(params, cfg, max_slots=3)
        prompts = [np.arange(5), np.arange(3) + 7, np.arange(9) + 2,
                   np.arange(2) + 30, np.arange(7) + 11]
        for p in prompts:
            eng.submit(p, max_tokens=6)
        done = sorted(eng.run_until_drained(), key=lambda r: r.uid)
        assert len(done) == len(prompts)
        for r, p in zip(done, prompts):
            assert r.generated == _reference_greedy(params, cfg, p, 6), r.uid

    def test_matches_host_loop_reference_engine(self):
        """Fused engine == the pre-refactor host-loop engine under greedy."""
        cfg = _cfg()
        params = _params(cfg)
        prompts = [np.arange(4), np.arange(6) + 3, np.arange(3) + 9]
        eng = _engine(params, cfg, max_slots=2)
        ref = ReferenceEngine(params, cfg,
                              ServeConfig(max_slots=2, max_len=64))
        for p in prompts:
            eng.submit(p, max_tokens=5)
            ref.submit(p, max_tokens=5)
        got = {r.uid: r.generated for r in eng.run_until_drained()}
        want = {r.uid: r.generated for r in ref.run_until_drained()}
        assert got == want


class TestEvictionRefill:
    def test_queue_deeper_than_slots_drains_with_refill(self):
        cfg = _cfg()
        params = _params(cfg)
        eng = _engine(params, cfg, max_slots=2)
        n = 7
        for i in range(n):
            eng.submit(np.arange(3) + i, max_tokens=3)
        done = eng.run_until_drained()
        assert len(done) == n
        assert all(len(r.generated) == 3 for r in done)
        # at most max_slots were ever simultaneously active
        assert max(m.active_slots for m in eng.metrics_log) <= 2
        # refill happened: more admission events than slots
        assert sum(m.admitted for m in eng.metrics_log) == n

    def test_evicted_slot_state_does_not_leak(self):
        """A refilled slot must decode from its own prompt, not the
        evicted occupant's cache."""
        cfg = _cfg()
        params = _params(cfg)
        eng = _engine(params, cfg, max_slots=1)
        p1, p2 = np.arange(5), np.arange(6) + 20
        eng.submit(p1, max_tokens=4)
        eng.submit(p2, max_tokens=4)
        done = sorted(eng.run_until_drained(), key=lambda r: r.uid)
        assert done[0].generated == _reference_greedy(params, cfg, p1, 4)
        assert done[1].generated == _reference_greedy(params, cfg, p2, 4)


class TestSampling:
    def test_per_slot_temperature_deterministic_given_seed(self):
        cfg = _cfg()
        params = _params(cfg)

        def run(seed):
            eng = _engine(params, cfg, max_slots=2, seed=seed)
            for i in range(4):
                eng.submit(np.arange(3) + i, max_tokens=5,
                           temperature=0.3 + 0.2 * i)
            return {r.uid: tuple(r.generated)
                    for r in eng.run_until_drained()}

        a, b, c = run(0), run(0), run(1)
        assert a == b                      # same seed -> identical streams
        assert a != c                      # seed actually feeds the slots

    def test_mixed_greedy_and_sampled_slots(self):
        """Greedy slots stay token-identical to the reference while sampled
        slots share the same batch."""
        cfg = _cfg()
        params = _params(cfg)
        eng = _engine(params, cfg, max_slots=2, seed=0)
        pg = np.arange(5)
        eng.submit(pg, max_tokens=5, temperature=0.0)
        eng.submit(np.arange(4) + 8, max_tokens=5, temperature=0.9)
        done = sorted(eng.run_until_drained(), key=lambda r: r.uid)
        assert done[0].generated == _reference_greedy(params, cfg, pg, 5)
        assert len(done[1].generated) == 5

    def test_eos_stops_generation(self):
        cfg = _cfg()
        params = _params(cfg)
        # find what greedy emits second, then make it the EOS id
        probe = _reference_greedy(params, cfg, np.arange(5), 3)
        eng = ServeEngine(params, cfg,
                          ServeConfig(max_slots=1, max_len=64,
                                      eos_id=probe[1]))
        eng.submit(np.arange(5), max_tokens=10)
        r = eng.run_until_drained()[0]
        assert r.generated == probe[:2]

    def test_eos_at_prefill_stops_immediately(self):
        cfg = _cfg()
        params = _params(cfg)
        probe = _reference_greedy(params, cfg, np.arange(5), 1)
        scfg = ServeConfig(max_slots=1, max_len=64, eos_id=probe[0])
        eng = ServeEngine(params, cfg, scfg)
        ref = ReferenceEngine(params, cfg, scfg)
        for e in (eng, ref):
            e.submit(np.arange(5), max_tokens=10)
        got = eng.run_until_drained()[0].generated
        want = ref.run_until_drained()[0].generated
        assert got == want == probe[:1]


class TestLengthCapEdges:
    def test_prompt_at_cap_engines_agree_and_respect_budget(self):
        """A prompt of max_len-1 finishes at admission with exactly one
        token in BOTH engines (total context capped at max_len)."""
        cfg = _cfg()
        params = _params(cfg)
        scfg = ServeConfig(max_slots=1, max_len=16)
        prompt = np.arange(15)
        eng = ServeEngine(params, cfg, scfg)
        ref = ReferenceEngine(params, cfg, scfg)
        for e in (eng, ref):
            e.submit(prompt, max_tokens=8)
        got = eng.run_until_drained()[0]
        want = ref.run_until_drained()[0]
        assert got.generated == want.generated
        assert len(prompt) + len(got.generated) <= scfg.max_len

    def test_non_pow2_max_len_does_not_truncate_prompt(self):
        """The admission bucket is clamped to max_len: a prompt longer than
        the previous pow2 bucket must not fall into prefill's ring branch
        (which would silently drop the oldest prompt tokens)."""
        cfg = _cfg()
        params = _params(cfg)
        prompt = np.arange(40)
        eng = ServeEngine(params, cfg,
                          ServeConfig(max_slots=1, max_len=48))
        eng.submit(prompt, max_tokens=4)
        r = eng.run_until_drained()[0]
        assert r.generated == _reference_greedy(params, cfg, prompt, 4,
                                                max_len=48)


class TestDeviceResidency:
    def test_single_trace_and_one_readback_per_tick(self):
        """The decode tick is ONE jitted call (traced once across the whole
        run) and the host reads back exactly one array per tick — the
        finished mask. No per-slot int(tok) syncs."""
        cfg = _cfg()
        params = _params(cfg)
        eng = _engine(params, cfg, max_slots=2)
        eng.submit(np.arange(4), max_tokens=8)
        eng.step()                          # admit + first decode tick
        assert eng.tick_trace_count == 1
        base = eng.host_readbacks
        # mid-flight ticks: no admission, no finishes -> exactly one
        # readback (the finished mask) per tick
        for i in range(4):
            assert eng.step() == []
            assert eng.host_readbacks == base + (i + 1)
        eng.run_until_drained()
        assert eng.tick_trace_count == 1    # never retraced

    def test_metrics_billed_to_accountant(self):
        from repro.core import accounting
        acct = accounting.CarbonAccountant(accounting.AccountantConfig(
            device="tpu_v5e", n_devices=1, grid_mix="NY"))
        cfg = _cfg()
        params = _params(cfg)
        eng = ServeEngine(params, cfg, ServeConfig(max_slots=2, max_len=64),
                          accountant=acct)
        for i in range(3):
            eng.submit(np.arange(4) + i, max_tokens=4)
        eng.run_until_drained()
        rep = acct.report()
        assert rep["tokens"] == sum(m.tokens for m in eng.metrics_log)
        assert rep["j_per_token"] is not None and rep["j_per_token"] > 0
        assert eng.summary()["decode_tokens_per_s"] > 0


class TestScheduler:
    def test_longest_prompt_first_admission_order(self):
        sched = Scheduler(SchedulerConfig(policy="longest_prompt"))
        for uid, n in enumerate([3, 9, 5, 7], start=1):
            sched.submit(Request(uid, np.arange(n)))
        picked = sched.select(2)
        assert [len(r.prompt) for r in picked] == [9, 7]
        assert len(sched) == 2
        sched.requeue_front(picked)
        assert len(sched) == 4

    def test_fifo_preserves_arrival_order(self):
        sched = Scheduler(SchedulerConfig(policy="fifo"))
        for uid, n in enumerate([3, 9, 5], start=1):
            sched.submit(Request(uid, np.arange(n)))
        assert [r.uid for r in sched.select(2)] == [1, 2]

    def test_longest_prompt_end_to_end(self):
        cfg = _cfg()
        params = _params(cfg)
        eng = ServeEngine(params, cfg, ServeConfig(max_slots=2, max_len=64),
                          scheduler=Scheduler(
                              SchedulerConfig(policy="longest_prompt")))
        prompts = {1: np.arange(3), 2: np.arange(9), 3: np.arange(6)}
        for p in prompts.values():
            eng.submit(p, max_tokens=4)
        done = eng.run_until_drained()
        assert len(done) == 3
        for r in done:
            assert r.generated == _reference_greedy(params, cfg,
                                                    prompts[r.uid], 4)


class TestDecodeKernel:
    def test_kernel_engine_token_identical(self):
        """Engine with the Pallas decode kernel (interpret mode on CPU) is
        token-identical to the XLA masked path."""
        cfg = _cfg()
        params = _params(cfg)
        eng = ServeEngine(params, cfg,
                          ServeConfig(max_slots=2, max_len=16,
                                      decode_kernel=True))
        prompts = [np.arange(4), np.arange(3) + 7]
        for p in prompts:
            eng.submit(p, max_tokens=3)
        done = sorted(eng.run_until_drained(), key=lambda r: r.uid)
        for r, p in zip(done, prompts):
            assert r.generated == _reference_greedy(params, cfg, p, 3,
                                                    max_len=16), r.uid

    def test_kernel_matches_masked_sdpa_ragged_lengths(self):
        """Direct kernel check: ragged lengths incl. a dead slot (0) and a
        sliding window, vs. the tag-masked SDPA the XLA path uses."""
        from repro.kernels import ops as kops
        from repro.models import layers
        rng = np.random.default_rng(3)
        b, s, h, hkv, d = 4, 24, 4, 2, 16
        q = jnp.asarray(rng.standard_normal((b, 1, h, d)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((b, s, hkv, d)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((b, s, hkv, d)), jnp.float32)
        lens = jnp.asarray([24, 10, 0, 1], jnp.int32)
        for window in (-1, 6):
            got = kops.decode_attention(q[:, 0], k, v, lens, scale=0.25,
                                        window=window, interpret=True)
            tags = jnp.where(jnp.arange(s)[None] < lens[:, None],
                             jnp.arange(s)[None], -1)
            q_pos = (lens - 1)[:, None]
            mask = layers.attention_mask(q_pos, tags, causal=True,
                                         window=window)
            mask &= (tags >= 0)[:, None, :]
            want = layers.sdpa(q, k, v, mask, 0.25)[:, 0]
            live = np.asarray(lens) > 0
            err = np.abs(np.asarray(got)[live]
                         - np.asarray(want)[live]).max()
            assert err < 1e-5, (window, err)
            assert np.abs(np.asarray(got)[~live]).max() == 0.0


class TestPaddedPrefill:
    def test_prefill_lengths_match_per_row(self):
        cfg = _cfg()
        params = _params(cfg)
        prompts = [np.arange(5), np.arange(3) + 7, np.arange(8) + 2]
        L = 8
        toks = np.zeros((3, L), np.int32)
        lens = np.array([len(p) for p in prompts], np.int32)
        for i, p in enumerate(prompts):
            toks[i, :len(p)] = p
        lg_b, cc_b = tf_lib.prefill(params, cfg, jnp.asarray(toks),
                                    max_len=32, cache_dtype=jnp.float32,
                                    lengths=jnp.asarray(lens))
        for i, p in enumerate(prompts):
            lg1, _ = tf_lib.prefill(params, cfg, jnp.asarray(p[None]),
                                    max_len=32, cache_dtype=jnp.float32)
            np.testing.assert_allclose(np.asarray(lg_b[i, 0]),
                                       np.asarray(lg1[0, -1]), atol=1e-5)
        # padded tag slots are invalidated
        tags = cc_b["pat0"]["pos"]          # (repeats, B, 32)
        for i, p in enumerate(prompts):
            row = np.asarray(tags[0, i])
            assert (row[:len(p)] == np.arange(len(p))).all()
            assert (row[len(p):] == -1).all()

    def test_padded_prefill_rejected_for_ssd(self):
        from repro.models import ssd as ssd_lib
        cfg = tf_lib.LMConfig(
            name="ssd", d_model=32, n_heads=2, n_kv_heads=2, d_ff=64,
            vocab=31, pattern=(tf_lib.BlockSpec(kind="ssd", has_ffn=False),),
            repeats=1, remat="none", vocab_pad_multiple=1,
            ssd_cfg=ssd_lib.SSDConfig(d_model=32, d_state=8, head_dim=16))
        # the guard fires before params are touched
        with pytest.raises(NotImplementedError):
            tf_lib.prefill({}, cfg, jnp.zeros((2, 8), jnp.int32),
                           max_len=16, lengths=jnp.asarray([4, 8]))
