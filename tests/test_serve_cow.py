"""Copy-on-write paged KV: fork semantics, n-best sampling, tree
speculation (DESIGN.md §18).

Covers the pool-level COW protocol (fork/writable/cow_write, the
retain-on-free guard, alloc_run failure booking, _unpublish pruning and
the audit orphan checks), engine-level n-best parity against independent
decode (fp32 and int8, greedy), tree-speculation stream identity, fork
behavior under the chaos tier, and a hypothesis property suite over
fork -> write -> release interleavings.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from hypothesis import given, settings, strategies as st

from repro.models import transformer as tf_lib
from repro.serve import (FaultPlan, PagePool, ServeConfig, ServeEngine,
                         generation_agreement, run_workload)
from repro.serve.pages import ROOT


def _cfg(vocab=61):
    return tf_lib.LMConfig(name="t", d_model=48, n_heads=4, n_kv_heads=2,
                           d_ff=96, vocab=vocab, pattern=(tf_lib.BlockSpec(),),
                           repeats=2, remat="none", vocab_pad_multiple=1)


def _params(cfg, seed=0):
    return tf_lib.init_lm(jax.random.PRNGKey(seed), cfg,
                          dtype=jnp.float32).params


def _paged(params, cfg, **kw):
    kw.setdefault("page_size", 4)
    kw.setdefault("max_slots", 4)
    kw.setdefault("max_len", 64)
    return ServeEngine(params, cfg, ServeConfig(paged=True, **kw))


# -----------------------------------------------------------------------------
# Pool-level COW protocol
# -----------------------------------------------------------------------------

class TestPoolCow:
    def test_fork_retains_and_freezes(self):
        pool = PagePool(6, page_size=4)
        run = pool.alloc(3)
        forked = pool.fork(run)
        assert forked == run                      # same physical ids
        assert all(pool.refcount(p) == 2 for p in run)
        assert pool.stats.forked_pages == 3
        # shared pages are frozen: no in-place writes, no compaction moves
        assert not any(pool.writable(p) for p in run)
        assert pool.movable_suffix(run) == len(run)
        pool.release_all(forked)
        assert all(pool.refcount(p) == 1 for p in run)
        assert all(pool.writable(p) for p in run)
        pool.release_all(run)
        assert pool.live == 0 and pool.audit() == []

    def test_cow_write_in_place_when_sole_owner(self):
        pool = PagePool(4, page_size=4)
        (p,) = pool.alloc(1)
        assert pool.cow_write(p) == (p, False)
        assert pool.stats.cow_copies == 0

    def test_cow_write_copies_shared_page(self):
        pool = PagePool(4, page_size=4)
        (p,) = pool.alloc(1)
        pool.fork([p])
        got = pool.cow_write(p)
        assert got is not None
        new, copied = got
        assert copied and new != p
        # the writer moved its reference to the private replacement; the
        # other holder keeps the original, now sole and writable again
        assert pool.refcount(p) == 1 and pool.refcount(new) == 1
        assert pool.writable(new)
        assert pool.stats.cow_copies == 1
        pool.release(p)
        pool.release(new)
        assert pool.live == 0 and pool.audit() == []

    def test_cow_write_copies_published_page(self):
        # a published page is frozen even at refcount 1: its bytes back a
        # registry key other admissions may hit
        pool = PagePool(4, page_size=4)
        (p,) = pool.alloc(1)
        pool.publish(p, ROOT, (1, 2, 3, 4))
        assert not pool.writable(p)
        new, copied = pool.cow_write(p)
        assert copied and new != p
        # the published original parks (evictable, still certifiable)
        assert pool.refcount(p) == 0 and p in pool.cached_pages()
        pool.release(new)
        assert pool.audit() == []

    def test_cow_write_exhausted_pool_returns_none(self):
        pool = PagePool(2, page_size=4)
        run = pool.alloc(2)
        pool.fork(run)
        before = pool.stats.cow_copies
        assert pool.cow_write(run[0]) is None
        # the shared page is untouched: both holders still reference it
        assert pool.refcount(run[0]) == 2
        assert pool.stats.cow_copies == before
        assert pool.audit() == []

    def test_retain_on_free_listed_page_raises(self):
        # S3: silently refcounting a free page would let alloc() hand the
        # same physical page to a second writer
        pool = PagePool(4, page_size=4)
        (p,) = pool.alloc(1)
        pool.release(p)                           # unpublished -> free list
        with pytest.raises(RuntimeError, match="free-listed"):
            pool.retain(p)
        assert pool.refcount(p) == 0 and pool.audit() == []

    def test_retain_parked_page_unparks(self):
        pool = PagePool(4, page_size=4)
        (p,) = pool.alloc(1)
        pool.publish(p, ROOT, (9, 9, 9, 9))
        pool.release(p)                           # published -> LRU park
        pool.retain(p)                            # cache-hit path: legal
        assert pool.refcount(p) == 1
        pool.release(p)
        assert pool.audit() == []

    def test_fork_free_page_raises_and_books_nothing(self):
        pool = PagePool(4, page_size=4)
        (p,) = pool.alloc(1)
        pool.release(p)
        with pytest.raises(RuntimeError):
            pool.fork([p])
        assert pool.stats.forked_pages == 0


class TestAllocRun:
    def test_alloc_run_failure_books_counter_and_nothing_else(self):
        # S1 regression: a failed contiguous-run request must book the
        # starvation counter and leave the pool byte-identical — no pages
        # taken, no refcounts bumped, no alloc_failures cross-booking
        pool = PagePool(8, page_size=4)
        held = [pool.alloc(1)[0] for _ in range(8)]
        for p in held[::2]:
            pool.release(p)                       # free list = every other
        free_before = sorted(pool._free)
        assert pool.alloc_run(2) is None
        assert pool.stats.alloc_run_failures == 1
        assert pool.stats.alloc_failures == 0
        assert sorted(pool._free) == free_before
        assert pool.audit() == []

    def test_alloc_run_success_books_no_failure(self):
        pool = PagePool(8, page_size=4)
        run = pool.alloc_run(3)
        assert run == [0, 1, 2]
        assert pool.stats.alloc_run_failures == 0
        pool.release_all(run)


class TestUnpublishPrune:
    def _chain(self, pool, blocks):
        pages, parent = [], ROOT
        for b in blocks:
            (p,) = pool.alloc(1)
            parent = pool.publish(p, parent, b)
            pages.append(p)
        return pages

    def test_unpublish_prunes_emptied_children_set(self):
        # S2: unpublishing a parent's last child must delete the emptied
        # set, not leave a zero-length entry for audit() to walk forever
        pool = PagePool(4, page_size=2)
        a, b = self._chain(pool, [(1, 2), (3, 4)])
        pool._unpublish(b)
        assert a not in pool._children
        assert pool.audit() == []
        pool.release_all([a, b])

    def test_cascade_unpublish_prunes_interior_entries(self):
        pool = PagePool(6, page_size=2)
        a, b, c = self._chain(pool, [(1, 2), (3, 4), (5, 6)])
        pool._unpublish(a)                        # cascades through b, c
        assert pool._children == {}
        assert pool._page_depth == {}
        assert pool.audit() == []
        pool.release_all([a, b, c])

    def test_audit_flags_orphaned_children_entries(self):
        # the S2 audit teeth: injected orphans are reported, not ignored
        pool = PagePool(4, page_size=2)
        (a,) = self._chain(pool, [(1, 2)])
        pool._children[a] = set()
        assert any("not pruned" in s for s in pool.audit())
        pool._children[a] = {3}
        assert any("no matching key" in s for s in pool.audit())
        del pool._children[a]
        pool._children[2] = {3}
        assert any("unpublished page" in s for s in pool.audit())

    def test_audit_flags_stale_depth_entry(self):
        pool = PagePool(4, page_size=2)
        pool._page_depth[1] = 0
        assert any("_page_depth" in s for s in pool.audit())


# -----------------------------------------------------------------------------
# Engine: n-best forks
# -----------------------------------------------------------------------------

PROMPTS = [np.arange(10) + 3, np.arange(7) + 20, np.arange(13) + 1]


def _nbest_run(params, cfg, n_best, prompts=PROMPTS, temperature=0.0, **kw):
    eng = _paged(params, cfg, temperature=temperature, **kw)
    uids = [eng.submit(p, max_tokens=8, n_best=n_best) for p in prompts]
    done = {r.uid: r for r in eng.run_until_drained()}
    assert eng.pool.audit() == []
    assert eng.pool.live == 0
    return eng, [done[u] for u in uids]


class TestNBestParity:
    def test_greedy_forks_match_independent_decode_fp32(self):
        cfg = _cfg()
        params = _params(cfg)
        eng, reqs = _nbest_run(params, cfg, n_best=3)
        # independent baseline: the same prompts decoded without forking
        base = _paged(params, cfg)
        gens = run_workload(base, PROMPTS, max_tokens=8)
        base_by_prompt = list(gens.values())
        for r, want in zip(reqs, base_by_prompt):
            assert r.nbest is not None and len(r.nbest) == 3
            assert list(r.generated) == list(r.nbest[0])
            for stream in r.nbest:
                assert list(stream) == list(want)
        s = eng.summary()
        assert s["forks"] == 2 * len(PROMPTS)
        # prompts of 10/7/13 tokens on 4-token pages all have a partial
        # boundary block -> each fork barrier pays k-1 copies
        assert s["cow_copies"] >= 2 * len(PROMPTS)
        assert s["fork_saved_bytes"] > 0

    def test_greedy_forks_match_independent_decode_int8(self):
        cfg = _cfg()
        params = _params(cfg)
        eng, reqs = _nbest_run(params, cfg, n_best=3, quant="int8")
        base = _paged(params, cfg, quant="int8")
        gens = run_workload(base, PROMPTS, max_tokens=8)
        for r, want in zip(reqs, gens.values()):
            for stream in r.nbest:
                assert list(stream) == list(want)

    def test_nbest_two_with_page_aligned_prompt(self):
        # page-aligned prompt: no partial boundary block, so the fork
        # shares every committed page and the barrier pays zero copies
        cfg = _cfg()
        params = _params(cfg)
        prompts = [np.arange(8) + 5]
        eng, reqs = _nbest_run(params, cfg, n_best=2, prompts=prompts)
        base = _paged(params, cfg)
        gens = run_workload(base, prompts, max_tokens=8)
        (want,) = gens.values()
        for stream in reqs[0].nbest:
            assert list(stream) == list(want)

    def test_temperature_forks_drain_clean(self):
        cfg = _cfg()
        params = _params(cfg)
        eng, reqs = _nbest_run(params, cfg, n_best=3, temperature=0.9)
        for r in reqs:
            assert len(r.nbest) == 3
            assert list(r.generated) == list(r.nbest[0])
            assert all(len(s) > 0 for s in r.nbest)

    def test_nbest_validation(self):
        cfg = _cfg()
        params = _params(cfg)
        eng = _paged(params, cfg)
        with pytest.raises(ValueError, match="n_best"):
            eng.submit(np.arange(4), n_best=0)
        with pytest.raises(ValueError):
            eng.submit(np.arange(4), n_best=5)    # > max_slots
        dense = ServeEngine(params, cfg, ServeConfig(max_slots=2, max_len=64))
        with pytest.raises(ValueError):
            dense.submit(np.arange(4), n_best=2)

    def test_cow_accounting_channels(self):
        from repro.core import accounting
        cfg = _cfg()
        params = _params(cfg)
        acct = accounting.CarbonAccountant(accounting.AccountantConfig(
            device="tpu_v5e", n_devices=1, grid_mix="NY"))
        eng = _paged(params, cfg)
        eng.accountant = acct
        eng.submit(np.arange(10) + 3, max_tokens=8, n_best=3)
        eng.run_until_drained()
        rep = acct.report()
        assert rep["forks"] == 2
        assert rep["cow_copies"] >= 2
        assert rep["cow_bytes"] > 0 and rep["cow_dram_j"] > 0
        assert rep["fork_saved_bytes"] > 0
        assert rep["fork_saved_dram_j"] > 0
        # COW copy traffic rides inside the grand total too
        assert rep["bytes_moved"] >= rep["cow_bytes"]
        s = eng.summary()
        assert s["cow_bytes"] == rep["cow_bytes"]
        assert s["pool_cow_copies"] >= 2
        assert s["pool_forked_pages"] > 0

    def test_forks_under_chaos_keep_streams_and_pool_clean(self):
        # PR 7 chaos tier x PR 8 forks: a seeded fault mid-decode must
        # leave every fork stream identical to the fault-free run and the
        # pool partition-clean at drain
        cfg = _cfg()
        params = _params(cfg)
        _, clean = _nbest_run(params, cfg, n_best=3)
        for kind in ("kv_bitflip", "nan_logits"):
            eng = _paged(params, cfg,
                         faults=FaultPlan.single(kind, tick=3, seed=11))
            uids = [eng.submit(p, max_tokens=8, n_best=3) for p in PROMPTS]
            done = {r.uid: r for r in eng.run_until_drained(max_ticks=400)}
            assert eng.pool.audit() == [], kind
            assert eng.pool.live == 0, kind
            got = [done[u] for u in uids]
            for r, want in zip(got, clean):
                assert [list(x) for x in r.nbest] == \
                    [list(x) for x in want.nbest], kind


# -----------------------------------------------------------------------------
# Engine: tree speculation
# -----------------------------------------------------------------------------

class TestTreeSpec:
    # repetitive prompts: the ngram drafter finds matches, trees branch
    REP = [np.tile([5, 9, 5, 9, 5], 4), np.tile([3, 4, 4, 3], 5),
           np.arange(11) + 2]

    def test_tree_stream_identical_to_plain_and_linear(self):
        cfg = _cfg()
        params = _params(cfg)
        plain = _paged(params, cfg, max_slots=2)
        g_plain = run_workload(plain, self.REP, max_tokens=10)
        linear = _paged(params, cfg, max_slots=2, spec_k=3)
        g_lin = run_workload(linear, self.REP, max_tokens=10)
        tree = _paged(params, cfg, max_slots=2, spec_k=3, spec_tree_m=3)
        g_tree = run_workload(tree, self.REP, max_tokens=10)
        assert generation_agreement(g_lin, g_plain)["identical"] == 1.0
        assert generation_agreement(g_tree, g_plain)["identical"] == 1.0
        assert tree.pool.audit() == []
        assert tree.pool.live == 0
        s = tree.summary()
        # the tree path went through the multi-branch verify
        assert s["accepted_tokens_per_tick"] >= 1.0

    def test_tree_at_least_linear_acceptance(self):
        cfg = _cfg()
        params = _params(cfg)
        linear = _paged(params, cfg, max_slots=2, spec_k=3)
        run_workload(linear, self.REP, max_tokens=10)
        tree = _paged(params, cfg, max_slots=2, spec_k=3, spec_tree_m=3)
        run_workload(tree, self.REP, max_tokens=10)
        # winner-by-argmax with branch-0 tie-break can only extend the
        # accepted prefix, never shrink it
        assert (tree.summary()["accepted_tokens_per_tick"]
                >= linear.summary()["accepted_tokens_per_tick"])

    def test_tree_config_validation(self):
        cfg = _cfg()
        params = _params(cfg)
        with pytest.raises(ValueError, match="spec_tree_m"):
            _paged(params, cfg, spec_tree_m=0)
        with pytest.raises(ValueError, match="spec_k"):
            _paged(params, cfg, spec_tree_m=2)
        with pytest.raises(ValueError, match="ngram"):
            _paged(params, cfg, spec_k=2, spec_tree_m=2,
                   spec_drafter="oracle")

    def test_tree_drafter_branch0_is_linear_drafter(self):
        from repro.serve import ngram_draft, ngram_draft_tree
        hist = jnp.asarray(np.random.default_rng(0).integers(
            0, 7, size=(3, 32)), jnp.int32)
        pos = jnp.asarray([12, 20, 31], jnp.int32)
        lin = ngram_draft(hist, pos, 4)
        tree = ngram_draft_tree(hist, pos, 4, 3)
        assert tree.shape == (3, 3, 4)
        np.testing.assert_array_equal(np.asarray(tree[:, 0]),
                                      np.asarray(lin))


# -----------------------------------------------------------------------------
# S4: property suite over fork -> write -> release interleavings
# -----------------------------------------------------------------------------

N_PAGES = 8


def _apply_ops(ops):
    """Drive a PagePool through an op tape, mirroring ownership host-side.

    ``owners`` maps an owner id to its list of held pages (a fork models
    one sibling's view of a shared run). Every op re-checks the audit
    invariants; the tape ends with a full teardown that must return the
    pool to pristine."""
    pool = PagePool(N_PAGES, page_size=4)
    owners = {}
    next_owner = 0
    writes = {}                  # page -> owner that last cow-wrote it
    for kind, a, b in ops:
        if kind == "alloc":
            run = pool.alloc(1 + a % 3)
            if run is not None:
                owners[next_owner] = run
                next_owner += 1
        elif kind == "fork" and owners:
            src = sorted(owners)[a % len(owners)]
            owners[next_owner] = pool.fork(owners[src])
            next_owner += 1
        elif kind == "cow" and owners:
            oid = sorted(owners)[a % len(owners)]
            run = owners[oid]
            idx = b % len(run)
            got = pool.cow_write(run[idx])
            if got is not None:
                page, copied = got
                run[idx] = page
                if copied:
                    # a COW copy must be private: no sibling may hold it
                    for other, orun in owners.items():
                        if other != oid:
                            assert page not in orun
                writes[page] = oid
        elif kind == "release" and owners:
            # quarantine teardown of one fork: drop every page it holds
            oid = sorted(owners)[a % len(owners)]
            pool.release_all(owners.pop(oid))
        assert pool.audit() == [], (kind, a, b)
        # partition: every page in exactly one of free / parked / live
        n_live = sum(1 for p in range(N_PAGES) if pool.refcount(p) > 0)
        assert n_live + pool.available == N_PAGES
        # surviving forks stay intact: every held page has a refcount
        for run in owners.values():
            assert all(pool.refcount(p) >= 1 for p in run)
    for run in owners.values():
        pool.release_all(run)
    assert pool.live == 0
    assert pool.audit() == []


@given(st.lists(st.tuples(
    st.sampled_from(["alloc", "fork", "cow", "release"]),
    st.integers(min_value=0, max_value=7),
    st.integers(min_value=0, max_value=7)), max_size=40))
@settings(max_examples=60, deadline=None)
def test_fork_write_release_interleavings(ops):
    _apply_ops(ops)


@given(st.integers(min_value=2, max_value=4),
       st.integers(min_value=1, max_value=3))
@settings(max_examples=20, deadline=None)
def test_k_way_fork_divergence_isolated(k, n_pages):
    """All k siblings cow-write the same shared run: every sibling ends on
    private pages, pairwise disjoint, with exactly k-1 copies per page
    (the last holder writes in place)."""
    pool = PagePool(n_pages * (k + 1), page_size=4)
    base = pool.alloc(n_pages)
    runs = [base] + [pool.fork(base) for _ in range(k - 1)]
    for run in runs:
        for i, p in enumerate(run):
            got = pool.cow_write(p)
            assert got is not None
            run[i] = got[0]
    assert pool.stats.cow_copies == (k - 1) * n_pages
    flat = [p for run in runs for p in run]
    assert len(set(flat)) == len(flat)            # pairwise disjoint
    assert all(pool.writable(p) for p in flat)
    for run in runs:
        pool.release_all(run)
    assert pool.live == 0 and pool.audit() == []
