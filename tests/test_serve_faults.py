"""Chaos tier (DESIGN.md §17): deterministic fault injection, the
detection rungs folded into the serve tick, and the graceful-degradation
ladder.

The load-bearing invariant, locked per fault kind: no injected fault may
crash the process, deadlock admission, or alter the token stream of ANY
request relative to the fault-free run — resilience costs joules
(recovery_j), never content. Plus: seeded plans replay bit-identically,
pool invariants hold across fault paths (hypothesis), summary ratios
0.0-guard their denominators on degenerate runs, and flag/config
validation fails fast with actionable messages.
"""

import argparse

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.models import transformer as tf_lib
from repro.serve import (FAULT_KINDS, TRANSIENT_FAULT_KINDS, FaultEvent,
                         FaultInjector, FaultPlan, GuardrailConfig, PagePool,
                         Scheduler, SchedulerConfig, ServeConfig, ServeEngine,
                         generation_agreement)
from repro.serve.engine import Request
from repro.serve.faults import GARBLE_VALUE, corrupt_kv_page
from repro.serve.pages import ROOT


def _cfg(vocab=61):
    return tf_lib.LMConfig(name="t", d_model=48, n_heads=4, n_kv_heads=2,
                           d_ff=96, vocab=vocab, pattern=(tf_lib.BlockSpec(),),
                           repeats=2, remat="none", vocab_pad_multiple=1)


@pytest.fixture(scope="module")
def model():
    cfg = _cfg()
    params = tf_lib.init_lm(jax.random.PRNGKey(0), cfg,
                            dtype=jnp.float32).params
    return cfg, params


PROMPTS = [np.arange(15), np.arange(11) + 7, np.arange(8) + 30]


def _run(model, plan=None, prompts=PROMPTS, max_tokens=8, guard=None,
         deadline=None, **cfg_kw):
    cfg, params = model
    cfg_kw.setdefault("paged", True)
    cfg_kw.setdefault("page_size", 4)
    kw = dict(max_slots=2, max_len=64, faults=plan, **cfg_kw)
    if guard is not None:
        kw["guard"] = guard
    eng = ServeEngine(params, cfg, ServeConfig(**kw))
    for p in prompts:
        eng.submit(p, max_tokens=max_tokens, deadline_ticks=deadline)
    done = eng.run_until_drained(max_ticks=400)
    return eng, {r.uid: list(r.generated) for r in done}


# -----------------------------------------------------------------------------
# Fault plan / injector determinism
# -----------------------------------------------------------------------------

class TestFaultPlan:
    def test_unknown_kind_rejected(self):
        with pytest.raises(ValueError, match="unknown fault kind"):
            FaultEvent(tick=1, kind="cosmic_ray")

    def test_negative_tick_rejected(self):
        with pytest.raises(ValueError, match="tick"):
            FaultEvent(tick=-1, kind="stall")

    def test_matrix_is_seed_deterministic(self):
        a = FaultPlan.matrix(seed=5, n_ticks=20)
        b = FaultPlan.matrix(seed=5, n_ticks=20)
        assert a == b
        assert {e.kind for e in a.events} == set(FAULT_KINDS)
        assert all(e.tick >= 1 for e in a.events)   # tick 0 admits cleanly
        assert FaultPlan.matrix(seed=6, n_ticks=20) != a

    def test_for_tick_and_max_tick(self):
        plan = FaultPlan.single("stall", tick=3)
        assert [e.kind for e in plan.for_tick(3)] == ["stall"]
        assert plan.for_tick(2) == []
        assert plan.max_tick == 3
        assert FaultPlan().max_tick == -1

    def test_injector_garble_choice_is_seeded(self):
        arr = np.zeros((2, 8), np.int32)
        picks = []
        for _ in range(2):
            inj = FaultInjector(FaultPlan.single("readback_garble", tick=0,
                                                 seed=9))
            out = inj.filter_readback(arr, tick=0, attempt=0)
            picks.append(int(np.flatnonzero(out.reshape(-1)
                                            == GARBLE_VALUE)[0]))
        assert picks[0] == picks[1]
        # retries see the true array: the torn-transfer model converges
        inj = FaultInjector(FaultPlan.single("readback_drop", tick=0))
        assert inj.filter_readback(arr, tick=0, attempt=0) is None
        assert inj.filter_readback(arr, tick=0, attempt=1) is arr

    def test_guardrail_validation(self):
        with pytest.raises(ValueError, match="audit_interval"):
            GuardrailConfig(audit_interval=-1)
        with pytest.raises(ValueError, match="spec_backoff_threshold"):
            GuardrailConfig(spec_backoff_threshold=1.5)
        with pytest.raises(ValueError, match="readback_max_retries"):
            GuardrailConfig(readback_max_retries=0)
        with pytest.raises(ValueError, match="drift_threshold"):
            GuardrailConfig(drift_threshold=-0.1)


# -----------------------------------------------------------------------------
# The chaos matrix: every fault kind, one invariant
# -----------------------------------------------------------------------------

class TestChaosMatrix:
    def test_every_kind_drains_stream_identical(self, model):
        """The tentpole invariant: each fault kind drains within budget
        and every request's stream matches the fault-free baseline token
        for token — detection + quarantine re-decode are invisible in
        content. Also: the pool ends clean (audit + zero live pages) and
        the cache tree ends NaN-free (quarantine teardown scrubs the
        poisoned private pages before they are recycled)."""
        _, base = _run(model)
        # transient kinds only: process_kill has no in-tick recovery — it
        # aborts the process and restarts via ServeEngine.restore(),
        # locked in tests/test_serve_snapshot.py (DESIGN.md §19)
        for kind in TRANSIENT_FAULT_KINDS:
            plan = FaultPlan.single(kind, tick=2, seed=11, slot=1)
            eng, got = _run(model, plan)
            s = eng.summary()
            assert s["faults_injected"] >= 1, kind
            assert got == base, kind
            assert eng.pool.audit() == [] and eng.pool.live == 0, kind
            for leaf in jax.tree.leaves(
                    [e["kv"] for e in eng.state.caches.values()]):
                if jnp.issubdtype(leaf.dtype, jnp.floating):
                    assert bool(jnp.all(jnp.isfinite(leaf))), kind

    def test_quarantine_bills_recovery_energy(self, model):
        eng, got = _run(model, FaultPlan.single("nan_logits", tick=2))
        s = eng.summary()
        assert s["quarantined"] >= 1
        assert s["recovery_tokens"] > 0
        assert s["recovery_j"] > 0.0
        assert s["recovery_j_per_token"] > 0.0
        assert 0.0 < s["quarantine_rate"] <= 1.0

    def test_same_plan_replays_identically(self, model):
        runs = []
        for _ in range(2):
            eng, got = _run(model, FaultPlan.single("kv_bitflip", tick=2,
                                                    seed=3))
            runs.append((got, eng.summary()))
        assert runs[0][0] == runs[1][0]
        for key in ("faults_injected", "quarantined", "shed", "ticks",
                    "recovery_tokens", "recovery_j"):
            assert runs[0][1][key] == runs[1][1][key], key

    def test_sampling_is_seed_reproducible(self, model):
        """Satellite: one explicit seed (ServeConfig.seed) makes even
        temperature-sampled serving replayable — the chaos diffing and the
        bench's --seed ride on this."""
        streams = []
        for _ in range(2):
            cfg, params = model
            eng = ServeEngine(params, cfg, ServeConfig(
                max_slots=2, max_len=64, paged=True, page_size=4, seed=123))
            for p in PROMPTS:
                eng.submit(p, max_tokens=6, temperature=0.8)
            done = eng.run_until_drained(max_ticks=400)
            streams.append({r.uid: list(r.generated) for r in done})
        assert streams[0] == streams[1]

    def test_audit_stays_clean_under_faults(self, model):
        guard = GuardrailConfig(audit_interval=1)
        eng, _ = _run(model, FaultPlan.single("kv_bitflip", tick=2),
                      guard=guard)
        assert eng.summary()["audit_failures"] == 0
        assert eng.audit_log == []

    def test_audit_detects_seeded_violation(self, model):
        cfg, params = model
        eng = ServeEngine(params, cfg, ServeConfig(
            max_slots=2, max_len=64, paged=True, page_size=4,
            guard=GuardrailConfig(audit_interval=1)))
        eng.submit(PROMPTS[0], max_tokens=4)
        eng.step()
        # engine claims a page the pool thinks is free: the ownership
        # reconciliation must see it (recorded, never raised)
        free_page = eng.pool._free[0]
        eng._slot_pages[0].append(free_page)
        eng.step()
        assert eng.audit_failures >= 1
        eng._slot_pages[0].remove(free_page)


# -----------------------------------------------------------------------------
# Readback transport faults
# -----------------------------------------------------------------------------

class TestReadbackGuard:
    @pytest.mark.parametrize("kind", ["readback_garble", "readback_drop"])
    def test_retry_recovers(self, model, kind):
        eng, got = _run(model, FaultPlan.single(kind, tick=2, seed=7))
        _, base = _run(model)
        s = eng.summary()
        assert s["readback_retries"] >= 1
        assert s["quarantined"] == 0        # transport != numerics
        assert got == base

    def test_retry_exhaustion_raises(self, model):
        """A persistently bad link (unlike the default torn-transfer
        model) must fail loudly after the retry budget, not spin."""
        cfg, params = model
        eng = ServeEngine(params, cfg, ServeConfig(
            max_slots=2, max_len=64, paged=True, page_size=4,
            faults=FaultPlan.single("readback_drop", tick=0),
            guard=GuardrailConfig(readback_max_retries=2)))
        eng._injector.filter_readback = lambda arr, tick, attempt=0: None
        eng.submit(PROMPTS[0], max_tokens=4)
        with pytest.raises(RuntimeError, match="readback"):
            eng.run_until_drained(max_ticks=10)


# -----------------------------------------------------------------------------
# Deadlines, aging, backpressure
# -----------------------------------------------------------------------------

class TestDeadlinesAndBackpressure:
    def test_deadline_sheds_overdue_queue(self, model):
        prompts = [np.arange(6) + 3 * i for i in range(8)]
        eng, got = _run(model, prompts=prompts, max_tokens=8, deadline=1)
        s = eng.summary()
        assert s["shed"] > 0
        assert len(got) == len(prompts)     # shed requests still complete
        assert 0.0 < s["shed_rate"] <= 1.0
        # shed + finished partitions the workload exactly
        assert eng.n_shed + eng.n_finished_ok == len(prompts)

    def test_submit_rejects_bad_deadline(self, model):
        cfg, params = model
        eng = ServeEngine(params, cfg, ServeConfig(max_slots=2, max_len=64))
        with pytest.raises(ValueError, match="deadline_ticks"):
            eng.submit(np.arange(4), deadline_ticks=0)

    def test_queue_aging_prevents_starvation(self):
        sched = Scheduler(SchedulerConfig(policy="longest_prompt",
                                          age_boost_ticks=1))
        old_short = Request(1, np.arange(4), submit_tick=0)
        new_long = Request(2, np.arange(10), submit_tick=100)
        sched.submit(old_short)
        sched.submit(new_long)
        # un-aged, length wins; with 100 ticks of waiting banked, the
        # short prompt outranks it (4 + 100 > 10)
        assert [r.uid for r in Scheduler(SchedulerConfig(
            policy="longest_prompt")).select(1)] == []
        assert [r.uid for r in sched.select(1, now=100)] == [1]

    def test_admission_retry_exhaustion_sheds(self, model):
        cfg, params = model
        eng = ServeEngine(params, cfg, ServeConfig(
            max_slots=2, max_len=64, paged=True, page_size=4,
            guard=GuardrailConfig(admit_max_retries=2, admit_backoff=1)))
        req = Request(99, np.arange(6), max_tokens=4,
                      submit_tick=0)
        eng._defer_admission(req, [], 0, 0, [])
        assert eng._defer_counts[99] == 1
        # exponential backoff parks the retry in the future
        assert eng._retry_after[99] > eng._tick_idx
        eng.scheduler.drop(lambda r: True)
        eng._defer_admission(req, [], 0, 0, [])
        eng.scheduler.drop(lambda r: True)
        eng._defer_admission(req, [], 0, 0, [])      # cap (2) exceeded
        assert req in eng._pending_shed
        assert 99 not in eng._defer_counts
        eng.scheduler.drop(lambda r: True)
        done = eng.step()
        assert [r.uid for r in done] == [99] and done[0].done
        assert eng.summary()["shed"] == 1


# -----------------------------------------------------------------------------
# Degradation ladder rungs
# -----------------------------------------------------------------------------

class TestDegradationLadder:
    def test_spec_backoff_on_acceptance_collapse(self, model):
        """Random prompts give the n-gram drafter near-zero acceptance;
        with the rung armed the engine walks spec-k down to 1 — and the
        stream stays identical to plain paged greedy (rejection sampling
        holds at every k)."""
        guard = GuardrailConfig(spec_backoff_threshold=0.9,
                                spec_backoff_window=2)
        eng, got = _run(model, spec_k=4, guard=guard, max_tokens=10)
        _, base = _run(model, max_tokens=10)
        s = eng.summary()
        assert s["spec_backoffs"] >= 1
        assert s["spec_k_current"] < 4
        assert s["degraded_ticks"] >= 1
        assert got == base

    def test_spec_backoff_off_by_default(self, model):
        eng, _ = _run(model, spec_k=4, max_tokens=10)
        s = eng.summary()
        assert s["spec_backoffs"] == 0 and s["spec_k_current"] == 4

    def test_compaction_pause_rung(self, model):
        cfg, params = model
        eng = ServeEngine(params, cfg, ServeConfig(
            max_slots=2, max_len=64, paged=True, page_size=4,
            compact_threshold=0.3,
            guard=GuardrailConfig(stall_factor=2.0, compact_pause_ticks=3)))
        for w in (0.01, 0.01, 0.01, 0.01):
            eng._maybe_pause_compaction(w)
        assert eng.compaction_pauses == 0
        eng._maybe_pause_compaction(0.05)    # > 2x the smoothed baseline
        assert eng.compaction_pauses == 1
        assert eng._compact_pause_until > eng._tick_idx
        assert eng._maybe_compact() == 0     # paused: no moves attempted

    def test_int8_drift_fallback_after_silent_corruption(self, model):
        """The silent-fault case the drift rung exists for: an int8 KV
        bit flip is finite garbage the numerics sentinel can NOT see; the
        periodic oracle check catches the disagreement and falls back to
        fp serving wholesale. Every request still completes."""
        cfg, params = model
        guard = GuardrailConfig(drift_check_interval=1, drift_min_checks=1,
                                drift_threshold=0.0, ewma_alpha=0.5)
        eng = ServeEngine(params, cfg, ServeConfig(
            max_slots=2, max_len=64, paged=True, page_size=4, quant="int8",
            faults=FaultPlan.single("kv_bitflip", tick=2, seed=3),
            guard=guard))
        for p in PROMPTS:
            eng.submit(p, max_tokens=8)
        done = eng.run_until_drained(max_ticks=400)
        assert len(done) == len(PROMPTS)
        assert all(len(r.generated) == 8 for r in done)
        assert eng.fp_fallbacks == 1
        assert eng.summary()["fp_fallbacks"] == 1
        assert eng.summary()["degraded_ticks"] >= 1

    def test_fp_fallback_requeues_live_slots(self, model):
        cfg, params = model
        eng = ServeEngine(params, cfg, ServeConfig(
            max_slots=2, max_len=64, paged=True, page_size=4, quant="int8"))
        for p in PROMPTS:
            eng.submit(p, max_tokens=6)
        for _ in range(3):
            eng.step()
        live = [r.uid for r in eng.slot_req if r is not None]
        assert live
        eng._fallback_to_fp()
        assert all(r is None for r in eng.slot_req)
        queued = [r.uid for r in eng.scheduler.pending]
        assert set(live) <= set(queued)
        done = eng.run_until_drained(max_ticks=400)
        got = {r.uid: len(r.generated) for r in done}
        assert got == {1: 6, 2: 6, 3: 6}
        assert eng.fp_fallbacks == 1
        eng._fallback_to_fp()                # one-way: second call no-ops
        assert eng.fp_fallbacks == 1


# -----------------------------------------------------------------------------
# Cache surgery primitives
# -----------------------------------------------------------------------------

class TestCacheSurgery:
    def test_corrupt_kv_page_float_nans_k_only(self, model):
        cfg, params = model
        eng = ServeEngine(params, cfg, ServeConfig(
            max_slots=2, max_len=64, paged=True, page_size=4))
        bad = corrupt_kv_page(eng.state.caches, 3)
        for name, entry in bad.items():
            kv = entry["kv"]
            idx = ((slice(None), 3) if name.startswith("pat") else (3,))
            assert bool(jnp.all(jnp.isnan(kv.k[idx])))
            assert bool(jnp.all(jnp.isfinite(kv.v[idx])))

    def test_corrupt_kv_page_int8_stays_finite(self, model):
        cfg, params = model
        eng = ServeEngine(params, cfg, ServeConfig(
            max_slots=2, max_len=64, paged=True, page_size=4, quant="int8"))
        before = {n: np.array(e["kv"].k) for n, e in eng.state.caches.items()}
        bad = corrupt_kv_page(eng.state.caches, 3)
        for name, entry in bad.items():
            kv = entry["kv"]
            idx = ((slice(None), 3) if name.startswith("pat") else (3,))
            assert kv.k.dtype == jnp.int8
            assert not np.array_equal(np.array(kv.k[idx]),
                                      before[name][idx])
            # scales untouched: the corruption dequantizes to in-range
            # finite values — silent by construction
            assert bool(jnp.all(jnp.isfinite(entry["kv_scale"].k)))

    def test_scrub_zeroes_private_pages(self, model):
        cfg, params = model
        eng = ServeEngine(params, cfg, ServeConfig(
            max_slots=2, max_len=64, paged=True, page_size=4))
        eng.submit(PROMPTS[0], max_tokens=6)
        eng.step()
        pages = list(eng._slot_pages[0])
        assert pages
        eng.state = __import__("dataclasses").replace(
            eng.state, caches=corrupt_kv_page(eng.state.caches, pages[-1]))
        eng._scrub_slot_storage(0)
        for name, entry in eng.state.caches.items():
            kv = entry["kv"]
            idx = ((slice(None), pages[-1]) if name.startswith("pat")
                   else (pages[-1],))
            assert bool(jnp.all(kv.k[idx] == 0))
            assert bool(jnp.all(kv.v[idx] == 0))


# -----------------------------------------------------------------------------
# Summary ratio guards (satellite: zero-division regression lock)
# -----------------------------------------------------------------------------

class TestSummaryGuards:
    @pytest.mark.parametrize("kw", [dict(), dict(paged=True, page_size=4),
                                    dict(paged=True, page_size=4, spec_k=2)])
    def test_empty_engine_summary_is_all_zeros(self, model, kw):
        cfg, params = model
        eng = ServeEngine(params, cfg, ServeConfig(max_slots=2, max_len=64,
                                                   **kw))
        s = eng.summary()
        for key in ("shed_rate", "quarantine_rate", "recovery_j_per_token",
                    "recovery_j", "faults_injected", "quarantined", "shed",
                    "degraded_ticks", "readback_retries", "fp_fallbacks",
                    "compaction_pauses", "audit_failures"):
            assert s[key] == 0, key
        assert s["decode_tokens_per_s"] == 0.0


# -----------------------------------------------------------------------------
# Config / flag validation (satellite)
# -----------------------------------------------------------------------------

class TestValidation:
    def test_engine_rejects_negative_spec_k(self, model):
        cfg, params = model
        with pytest.raises(ValueError, match="spec_k"):
            ServeEngine(params, cfg, ServeConfig(
                max_slots=2, max_len=64, paged=True, spec_k=-1))

    def test_engine_rejects_misaligned_prefill_chunk(self, model):
        cfg, params = model
        with pytest.raises(ValueError, match="prefill_chunk"):
            ServeEngine(params, cfg, ServeConfig(
                max_slots=2, max_len=64, paged=True, page_size=4,
                prefill_chunk=6))

    def test_engine_rejects_out_of_range_compact_threshold(self, model):
        cfg, params = model
        with pytest.raises(ValueError, match="compact_threshold"):
            ServeEngine(params, cfg, ServeConfig(
                max_slots=2, max_len=64, paged=True, page_size=4,
                compact_threshold=1.5))

    def _ns(self, **over):
        ns = argparse.Namespace(
            spec_k=0, page_size=16, prefill_chunk=0, compact_threshold=0.0,
            num_pages=None, paged=False, fault_kind=None, fault_tick=2,
            deadline_ticks=None, slots=4, nbest=1, spec_tree_m=1,
            spec_drafter="ngram", checkpoint_dir=None,
            checkpoint_interval=0, resume=False)
        vars(ns).update(over)
        return ns

    @pytest.mark.parametrize("over", [
        dict(spec_k=-1), dict(page_size=0),
        dict(paged=True, prefill_chunk=6, page_size=4),
        dict(compact_threshold=2.0), dict(num_pages=0),
        dict(spec_k=2, paged=False), dict(deadline_ticks=0),
        dict(fault_kind="stall", fault_tick=-1),
        dict(nbest=0), dict(nbest=2, paged=False),
        dict(nbest=8, slots=4, paged=True),
        dict(spec_tree_m=0), dict(spec_tree_m=2, spec_k=0, paged=True),
        dict(spec_tree_m=2, spec_k=2, paged=True, spec_drafter="oracle"),
        dict(checkpoint_interval=-1),
        dict(checkpoint_interval=2, checkpoint_dir=None),
        dict(resume=True, checkpoint_dir=None),
        dict(fault_kind="process_kill", checkpoint_dir=None)])
    def test_launcher_rejects_bad_flags(self, over):
        from repro.launch.serve import validate_args
        with pytest.raises(SystemExit):
            validate_args(argparse.ArgumentParser(), self._ns(**over))

    def test_launcher_accepts_good_flags(self):
        from repro.launch.serve import validate_args
        validate_args(argparse.ArgumentParser(),
                      self._ns(paged=True, prefill_chunk=32, page_size=16,
                               spec_k=2, fault_kind="nan_logits"))
        validate_args(argparse.ArgumentParser(),
                      self._ns(checkpoint_dir="ckpt", checkpoint_interval=3,
                               resume=True, fault_kind="process_kill"))


# -----------------------------------------------------------------------------
# Pool invariants across fault paths (hypothesis)
# -----------------------------------------------------------------------------

class TestPoolInvariantsUnderFaults:
    @settings(max_examples=25, deadline=None)
    @given(st.integers(4, 24), st.lists(st.integers(0, 5), min_size=1,
                                        max_size=24),
           st.integers(0, 2 ** 31 - 1))
    def test_spike_hold_release_cycle_keeps_audit_clean(
            self, num_pages, ops, seed):
        """The pool_spike fault path is alloc-hold-release interleaved
        with normal slot traffic and publishes. Whatever the interleaving,
        the allocator's invariants hold: audit() is clean at every step
        and all pages return to free once every owner lets go."""
        pool = PagePool(num_pages, 4)
        rs = np.random.default_rng(seed)
        pool._free = list(rs.permutation(pool._free))
        holds, slots, pubs = [], [], []
        parent, depth = ROOT, 0
        for op in ops:
            if op == 0:                       # co-tenant spike
                got = pool.alloc(int(rs.integers(1, 4)))
                if got is not None:
                    holds.append(got)
            elif op == 1 and holds:           # spike expiry
                pool.release_all(holds.pop())
            elif op == 2:                     # slot admission
                got = pool.alloc(int(rs.integers(1, 3)))
                if got is not None:
                    slots.append(got)
            elif op == 3 and slots:           # quarantine teardown
                pool.release_all(slots.pop())
            elif op == 4:                     # healthy finish: publish
                got = pool.alloc(1)
                if got is not None:
                    parent = pool.publish(got[0], parent, (depth,) * 4)
                    depth += 1
                    pubs.append(got)
            elif op == 5:                     # over-ask must fail clean
                before = pool.available
                assert pool.alloc(num_pages + 1) is None
                assert pool.available == before
            assert pool.audit() == []
        for owned in holds + slots + pubs:
            pool.release_all(owned)
        assert pool.audit() == []
        # every owner let go: nothing live (published pages park in the
        # LRU, which counts as allocatable)
        assert pool.live == 0
