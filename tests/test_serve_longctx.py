"""Long-context serving tier (DESIGN.md §16).

Covers the paged flash-prefill kernel (ops-level parity vs a dense
reference and transformer-level parity vs the chunked-gather oracle,
fp32/int8 x contiguous/fragmented layouts), the gather-byte accounting
fix, page-table compaction (engine stream-identity + pool invariants,
property-based), cost-aware prefix eviction, the `_bucket_len`
executable-ladder boundary, and the scheduler's defer-vs-drop edge at the
page budget.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import accounting
from repro.kernels import ops as kops
from repro.models import costing
from repro.models import transformer as tf_lib
from repro.serve import (PagePool, Request, ServeConfig, ServeEngine,
                         fragmentation, generation_agreement, run_workload)
from repro.serve.engine import _bucket_len
from repro.serve.pages import ROOT, block_tokens


def _cfg(**kw):
    kw.setdefault("quant", tf_lib.QuantPolicy())
    return tf_lib.LMConfig(name="t", d_model=48, n_heads=4, n_kv_heads=2,
                           d_ff=96, vocab=61, pattern=(tf_lib.BlockSpec(),),
                           repeats=2, remat="none", vocab_pad_multiple=1,
                           **kw)


def _params(cfg, seed=0):
    return tf_lib.init_lm(jax.random.PRNGKey(seed), cfg,
                          dtype=jnp.float32).params


# -----------------------------------------------------------------------------
# Paged flash-prefill kernel: ops-level parity vs a dense reference
# -----------------------------------------------------------------------------

def _reference(q, k_new, v_new, k_pool, v_pool, pt, starts, lens, *,
               scale, window, k_scale=None, v_scale=None):
    """Dense oracle: gather each row's cached window (dequantizing like
    the decode path), append the in-flight chunk, run masked softmax."""
    b, c, h, d = q.shape
    hkv = k_pool.shape[2]
    rep = h // hkv
    ps = k_pool.shape[1]
    out = np.zeros_like(np.asarray(q, np.float32))
    for bi in range(b):
        start, ln = int(starts[bi]), int(lens[bi])
        nbk = -(-max(start, 1) // ps) if start > 0 else 0
        ks, vs = [], []
        for j in range(nbk):
            page = int(pt[bi, j])
            kk = np.asarray(k_pool[page], np.float32)
            vv = np.asarray(v_pool[page], np.float32)
            if k_scale is not None:
                kk = kk * np.asarray(k_scale[page], np.float32)[..., None]
                vv = vv * np.asarray(v_scale[page], np.float32)[..., None]
            ks.append(kk)
            vs.append(vv)
        kc = np.concatenate(ks, 0)[:start] if ks else np.zeros((0, hkv, d))
        vc = np.concatenate(vs, 0)[:start] if vs else np.zeros((0, hkv, d))
        k_all = np.concatenate([kc, np.asarray(k_new[bi], np.float32)], 0)
        v_all = np.concatenate([vc, np.asarray(v_new[bi], np.float32)], 0)
        k_pos = np.arange(start + c)
        for t in range(ln):
            q_abs = start + t
            valid = k_pos <= q_abs
            valid &= (k_pos < start) | (k_pos - start < ln)
            if window > 0:
                valid &= q_abs - k_pos < window
            for hi in range(h):
                logits = (np.asarray(q[bi, t, hi], np.float32)
                          @ k_all[:, hi // rep].T) * scale
                logits = np.where(valid, logits, -np.inf)
                w = np.exp(logits - logits.max())
                w /= w.sum()
                out[bi, t, hi] = w @ v_all[:, hi // rep]
    return out


class TestPagedPrefillKernelOps:
    @pytest.mark.parametrize("quantized", [False, True])
    @pytest.mark.parametrize("window", [-1, 6])
    @pytest.mark.parametrize("frag", [False, True])
    def test_matches_dense_reference(self, quantized, window, frag):
        b, c, h, hkv, d, ps, npages, nbk = 3, 5, 4, 2, 8, 4, 16, 4
        rng = np.random.default_rng(0)
        q = jnp.asarray(rng.normal(size=(b, c, h, d)), jnp.float32)
        k_new = jnp.asarray(rng.normal(size=(b, c, hkv, d)), jnp.float32)
        v_new = jnp.asarray(rng.normal(size=(b, c, hkv, d)), jnp.float32)
        kf = jnp.asarray(rng.normal(size=(npages, ps, hkv, d)), jnp.float32)
        vf = jnp.asarray(rng.normal(size=(npages, ps, hkv, d)), jnp.float32)
        if quantized:
            from repro.quant.int8 import quantize_rowwise
            (k_pool, k_scale) = quantize_rowwise(kf)
            (v_pool, v_scale) = quantize_rowwise(vf)
            kf = (k_pool.astype(jnp.float32)
                  * k_scale.astype(jnp.float32)[..., None])
            vf = (v_pool.astype(jnp.float32)
                  * v_scale.astype(jnp.float32)[..., None])
        else:
            k_pool, v_pool, k_scale, v_scale = kf, vf, None, None
        order = (rng.permutation(npages)[: b * nbk] if frag
                 else np.arange(b * nbk))
        pt = jnp.asarray(order.reshape(b, nbk), jnp.int32)
        # unaligned start, short row, dead row
        starts = jnp.asarray([13, 4, 0], jnp.int32)
        lens = jnp.asarray([5, 3, 0], jnp.int32)
        scale = 1.0 / np.sqrt(d)
        got = kops.paged_prefill_attention(
            q, k_new, v_new, k_pool, v_pool, pt, starts, lens,
            scale=scale, window=window, k_scale=k_scale, v_scale=v_scale)
        want = _reference(q, k_new, v_new, kf, vf, np.asarray(pt),
                          np.asarray(starts), np.asarray(lens),
                          scale=scale, window=window)
        mask = (np.arange(c)[None, :]
                < np.asarray(lens)[:, None])[..., None, None]
        err = np.max(np.abs(np.asarray(got) * mask - want * mask))
        assert err < 1e-5, err
        # dead rows (len 0) produce exact zeros, not garbage
        assert np.all(np.asarray(got)[2] == 0.0)


# -----------------------------------------------------------------------------
# Transformer-level parity: kernel path vs the chunked-gather oracle
# -----------------------------------------------------------------------------

class TestPagedExtendKernelParity:
    @pytest.mark.parametrize("quant", [tf_lib.QuantPolicy(),
                                       tf_lib.INT8_QUANT],
                             ids=["fp32", "int8"])
    @pytest.mark.parametrize("frag", [False, True],
                             ids=["contiguous", "fragmented"])
    def test_logits_and_cache_match_oracle(self, quant, frag):
        cfg = _cfg(quant=quant)
        params = _params(cfg)
        ps, npages, nslots, nblk = 4, 16, 2, 8
        caches = tf_lib.init_paged_caches(cfg, num_pages=npages,
                                          page_size=ps, dtype=jnp.float32)
        rng = np.random.default_rng(3)
        order = (rng.permutation(npages)[: nslots * nblk] if frag
                 else np.arange(nslots * nblk))
        pt = jnp.asarray(order.reshape(nslots, nblk), jnp.int32)
        chunks = ((7, 5), (6, 0))       # ragged, incl. a dead second chunk
        width = 8
        toks = [jnp.asarray(rng.integers(0, 61, size=(nslots, width)),
                            jnp.int32) for _ in chunks]
        outs = {}
        for kern in (False, True):
            c2 = caches
            cfg2 = dataclasses.replace(cfg, decode_kernel=kern)
            starts = jnp.zeros((nslots,), jnp.int32)
            logits_all = []
            for chunk, tk in zip(chunks, toks):
                lens = jnp.asarray(chunk, jnp.int32)
                logits, c2 = tf_lib.paged_extend(params, cfg2, tk, starts,
                                                 lens, pt, c2)
                m = (np.arange(width)[None, :]
                     < np.asarray(lens)[:, None])[..., None]
                logits_all.append(np.asarray(logits) * m)
                starts = starts + lens
            outs[kern] = (logits_all, c2)
        for a, b in zip(outs[False][0], outs[True][0]):
            assert np.max(np.abs(a - b)) < 1e-4
        # cache parity outside the sink page (padding rows dump
        # path-dependent garbage there by design)
        from jax.tree_util import keystr, tree_flatten_with_path
        la, _ = tree_flatten_with_path(outs[False][1])
        lb = jax.tree.leaves(outs[True][1])
        for (path, x), y in zip(la, lb):
            x = np.asarray(x, np.float32)
            y = np.asarray(y, np.float32)
            ax = 1 if "pat" in keystr(path) else 0
            x = np.delete(x, npages, axis=ax)
            y = np.delete(y, npages, axis=ax)
            assert np.max(np.abs(x - y)) < 1e-5, keystr(path)


# -----------------------------------------------------------------------------
# Gather-byte accounting (the under-billing fix)
# -----------------------------------------------------------------------------

def _engine(params, cfg, **kw):
    kw.setdefault("max_slots", 2)
    kw.setdefault("max_len", 64)
    kw.setdefault("paged", True)
    kw.setdefault("page_size", 4)
    return ServeEngine(params, cfg, ServeConfig(**kw))


PROMPTS = [np.arange(23) % 50, np.arange(11) % 50 + 3, np.arange(17) % 50]


class TestGatherAccounting:
    def test_xla_path_bills_whole_table_per_admit_tick(self):
        cfg = _cfg()
        eng = _engine(_params(cfg), cfg, decode_kernel=False,
                      prefill_chunk=8, prefix_cache=False)
        run_workload(eng, PROMPTS, max_tokens=4)
        n_admit = sum(1 for m in eng.metrics_log if m.prefill_tokens > 0)
        nb = eng._blocks_per_slot
        expect = (eng._kv_token_bytes * eng.scfg.max_slots * nb
                  * eng.scfg.page_size * n_admit)
        got = sum(m.prefill_gather_bytes for m in eng.metrics_log)
        assert got == pytest.approx(expect)

    def test_kernel_path_bills_page_granular_window(self):
        cfg = _cfg()
        kw = dict(prefill_chunk=8, prefix_cache=False)
        xla = _engine(_params(cfg), cfg, decode_kernel=False, **kw)
        kern = _engine(_params(cfg), cfg, decode_kernel=True, **kw)
        for eng in (xla, kern):
            run_workload(eng, PROMPTS, max_tokens=4)
        gb = lambda e: sum(m.prefill_gather_bytes for m in e.metrics_log)
        assert 0 < gb(kern) < gb(xla)
        ps = kern.scfg.page_size
        # page-granular: sum over chunks of ceil(start/ps)*ps tokens.
        # chunk boundaries are multiples of 8 here, so per prompt of
        # length L the windows are 8, 16, ... below L, page-aligned
        expect = 0.0
        for p in PROMPTS:
            starts = range(8, len(p), 8)
            expect += sum(-(-s // ps) * ps for s in starts)
        assert gb(kern) == pytest.approx(kern._kv_token_bytes * expect)

    def test_gather_is_part_of_kv_bytes_and_ledgered(self):
        cfg = _cfg()
        acct = accounting.CarbonAccountant(accounting.AccountantConfig(
            device="tpu_v5e", n_devices=1, grid_mix="NY"))
        eng = _engine(_params(cfg), cfg, decode_kernel=True,
                      prefill_chunk=8)
        eng.accountant = acct
        run_workload(eng, PROMPTS, max_tokens=4)
        for m in eng.metrics_log:
            assert m.prefill_gather_bytes <= m.kv_bytes + 1e-9
        rep = acct.report()
        total = sum(m.prefill_gather_bytes for m in eng.metrics_log)
        assert rep["prefill_gather_bytes"] == pytest.approx(total)
        assert rep["prefill_gather_dram_j"] >= 0.0
        assert rep["compaction_moves"] == 0
        assert eng.summary()["prefill_gather_bytes"] == pytest.approx(total)


# -----------------------------------------------------------------------------
# Page-table compaction
# -----------------------------------------------------------------------------

class TestCompactionPool:
    def test_movable_suffix_pins_published_and_shared(self):
        pool = PagePool(8, 4)
        pages = pool.alloc(4)
        pool.publish(pages[0], ROOT, (1, 2, 3, 4))
        assert pool.movable_suffix(pages) == 1       # published root pinned
        pool.retain(pages[2])                        # shared mid-page
        assert pool.movable_suffix(pages) == 3
        pool.release(pages[2])
        assert pool.movable_suffix(pages) == 1

    def test_alloc_run_contiguous_and_never_evicts(self):
        pool = PagePool(8, 4)
        held = pool.alloc(8)
        # park two published blocks; free list is empty
        for p in held[:2]:
            pool.publish(p, ROOT if p == held[0] else held[0], (p,) * 4)
            pool.release(p)
        assert pool.alloc_run(2) is None             # must NOT evict park
        assert len(pool.cached_pages()) == 2
        pool.release_all(held[2:])
        run = pool.alloc_run(3)
        assert run == sorted(run)
        assert all(b == a + 1 for a, b in zip(run, run[1:]))
        assert [pool.refcount(p) for p in run] == [1, 1, 1]

    def test_fragmentation_score(self):
        assert fragmentation([0, 1, 2, 3]) == 0.0
        assert fragmentation([3, 1, 0, 2]) == 1.0
        assert fragmentation([0, 1, 7, 8]) == pytest.approx(1 / 3)
        assert fragmentation([5]) == 0.0
        assert fragmentation([]) == 0.0


class TestCompactionEngine:
    def test_forced_compact_streams_identical_and_counted(self):
        cfg = _cfg()
        params = _params(cfg)
        prompts = [np.arange(n) % 50 for n in (29, 17, 25, 9)]

        def run(compact):
            eng = _engine(params, cfg, decode_kernel=True, prefill_chunk=8,
                          num_pages=24, compact_threshold=compact)
            rs = np.random.default_rng(5)
            eng.pool._free = list(rs.permutation(eng.pool._free))
            gens = run_workload(eng, prompts, max_tokens=6)
            return eng, gens

        plain, g0 = run(0.0)
        compacted, g1 = run(0.05)
        moves = sum(m.compaction_moves for m in compacted.metrics_log)
        assert moves > 0
        assert compacted.compact_trace_count == 1    # one executable
        assert generation_agreement(g1, g0)["identical"] == 1.0
        # all pages returned after drain; the copy traffic was billed
        assert compacted.pool.live == 0
        billed = sum(m.kv_bytes for m in compacted.metrics_log)
        assert billed > sum(m.kv_bytes for m in plain.metrics_log)

    def test_compacted_slot_table_is_contiguous(self):
        cfg = _cfg()
        eng = _engine(_params(cfg), cfg, decode_kernel=True,
                      prefill_chunk=8, num_pages=24, compact_threshold=0.05,
                      prefix_cache=False)
        # pool pops from the END of _free: hand the slot scattered low
        # pages while a contiguous high run stays free for alloc_run
        eng.pool._free = list(range(12, 24)) + [11, 9, 7, 5, 3, 1, 0, 2,
                                                4, 6, 8, 10]
        eng.submit(np.arange(13) % 50, max_tokens=12)
        saw_compact = False
        for _ in range(40):
            eng.step()
            if eng.last_metrics.compaction_moves:
                saw_compact = True
                pages = eng._slot_pages[0]
                lo = eng.pool.movable_suffix(pages)
                assert fragmentation(pages[lo:]) == 0.0
                # device table row matches the host mirror
                row = np.asarray(eng.state.page_table)[0][:len(pages)]
                assert list(row) == pages
            if all(r is None for r in eng.slot_req):
                break
        assert saw_compact


class TestCompactionProperty:
    @settings(max_examples=30, deadline=None)
    @given(st.integers(2, 20), st.integers(0, 6), st.integers(2, 8),
           st.integers(0, 2 ** 31 - 1))
    def test_compact_cycle_conserves_pool_invariants(
            self, num_pages, chain_len, suffix_len, seed):
        """A compaction cycle (movable_suffix -> alloc_run -> release old)
        conserves total refcounts, leaves the prefix registry untouched,
        and keeps every page in exactly one allocator state."""
        total = chain_len + suffix_len
        if total == 0 or total > num_pages:
            return
        pool = PagePool(num_pages, 4)
        rs = np.random.default_rng(seed)
        pool._free = list(rs.permutation(pool._free))
        chain = pool.alloc(chain_len) or []
        parent = ROOT
        for i, p in enumerate(chain):
            parent = pool.publish(p, parent, (i,) * 4)
        suffix = pool.alloc(suffix_len) or []
        pages = chain + suffix
        refs_before = list(pool._ref)
        registry_before = dict(pool._key_to_page)
        depth_before = dict(pool._page_depth)
        lo = pool.movable_suffix(pages)
        assert lo == chain_len      # published chain pinned, suffix movable
        movable = pages[lo:]
        run = pool.alloc_run(len(movable))
        if run is not None:
            pool.release_all(movable)
            pages = pages[:lo] + run
            assert all(b == a + 1 for a, b in zip(run, run[1:]))
        # refcount conservation: same number of live references
        assert sum(pool._ref) == sum(refs_before)
        # registry/published prefixes byte-identical
        assert pool._key_to_page == registry_before
        assert pool._page_depth == depth_before
        assert pool.stats.evicted_blocks == 0
        # every page in exactly one state: free, parked, or live
        states = sorted(pool._free) + sorted(pool._lru) + sorted(
            p for p in range(num_pages) if pool._ref[p] > 0)
        assert sorted(states) == list(range(num_pages))
        # the full chain still certifies
        assert pool.lookup([(i,) * 4 for i in range(chain_len)]) == chain


# -----------------------------------------------------------------------------
# Cost-aware eviction
# -----------------------------------------------------------------------------

class TestCostEviction:
    def test_block_recompute_flops_formula_and_monotonicity(self):
        e, l, a = 1000.0, 2, 64
        n = 4
        # depth 0: 2*E*n + 4*l*a*(1+2+3+4)
        assert costing.block_recompute_flops(e, l, a, 0, n) == \
            pytest.approx(2 * e * n + 4 * l * a * 10)
        d1 = costing.block_recompute_flops(e, l, a, n, n)
        d0 = costing.block_recompute_flops(e, l, a, 0, n)
        assert d1 > d0                  # deeper blocks cost strictly more

    def _chained_pool(self, policy):
        pool = PagePool(3, 4, evict_policy=policy,
                        block_cost=lambda d: float(d + 1))
        a = pool.alloc(2)               # chain A: two blocks (old)
        pool.publish(a[0], ROOT, (0,) * 4)
        pool.publish(a[1], a[0], (1,) * 4)
        pool.release_all(a)
        b = pool.alloc(1)               # chain B: one block (recent)
        pool.publish(b[0], ROOT, (9,) * 4)
        pool.release_all(b)
        return pool, a, b

    def test_cost_policy_trims_cheapest_leaf_keeps_deep_chain(self):
        pool, a, b = self._chained_pool("cost")
        got = pool.alloc(1)
        assert got == [b[0]]            # cheapest leaf (depth 0, no kids)
        # chain A survives intact and still certifies
        assert pool.lookup([(0,) * 4, (1,) * 4]) == a

    def test_lru_policy_evicts_oldest_and_cascades(self):
        pool, a, b = self._chained_pool("lru")
        got = pool.alloc(1)
        assert got == [a[0]]            # oldest parked = chain A's root
        # the cascade wiped A's child key; B still certifies
        assert pool.lookup([(0,) * 4, (1,) * 4]) == []
        assert pool.lookup([(9,) * 4]) == [b[0]]

    def test_engine_wires_cost_policy(self):
        cfg = _cfg()
        eng = _engine(_params(cfg), cfg, evict_policy="cost")
        assert eng.pool.evict_policy == "cost"
        assert eng.pool.block_cost(1) > eng.pool.block_cost(0) > 0
        with pytest.raises(ValueError):
            _engine(_params(cfg), cfg, evict_policy="mru")


# -----------------------------------------------------------------------------
# _bucket_len executable-ladder boundary (satellite regression)
# -----------------------------------------------------------------------------

class TestBucketBoundary:
    def test_exact_pow2_stays_in_its_bucket(self):
        assert _bucket_len(16) == 16            # NOT 32
        assert _bucket_len(32, cap=32) == 32
        assert _bucket_len(17) == 32
        assert _bucket_len(4) == 4
        assert _bucket_len(1) == 4
        assert _bucket_len(8, cap=8) == 8
        # non-pow2 cap clamps the ladder at the cap itself
        assert _bucket_len(20, cap=24) == 24
        assert _bucket_len(24, cap=24) == 24

    def test_chunk_multiple_prompts_trace_one_bucket(self):
        """Prompts landing exactly on chunk-size multiples must reuse the
        single chunk-width executable — a boundary off-by-one here would
        recompile in steady state."""
        cfg = _cfg()
        eng = _engine(_params(cfg), cfg, prefill_chunk=8)
        prompts = [np.arange(16) % 50, np.arange(8) % 50,
                   np.arange(24) % 50, np.arange(16) % 50 + 1]
        run_workload(eng, prompts, max_tokens=3)
        assert eng.admit_trace_counts == {8: 1}


# -----------------------------------------------------------------------------
# Scheduler: defer-vs-drop at the page budget
# -----------------------------------------------------------------------------

class TestDeferVsDrop:
    def test_exact_fit_defers_until_capacity_then_completes(self):
        cfg = _cfg()
        # pool of 8 pages; the big request needs exactly 8 -> must defer
        # while the small one holds pages, then admit, never drop
        eng = _engine(_params(cfg), cfg, num_pages=8, prefix_cache=False)
        eng.submit(np.arange(9) % 50, max_tokens=3)     # needs 3 pages
        eng.step()                                      # small now resident
        big = eng.submit(np.arange(19) % 50, max_tokens=13)   # needs 8
        eng.step()
        # deferred, not dropped: still queued, books nothing
        assert len(eng.scheduler) == 1
        assert eng.pool.stats.hit_blocks == eng.pool.stats.missed_blocks == 0
        done = eng.run_until_drained()
        by_uid = {r.uid: r for r in done}
        assert len(by_uid[big].generated) == 13         # ran to completion

    def test_over_budget_request_drops_fast(self):
        cfg = _cfg()
        eng = _engine(_params(cfg), cfg, num_pages=8)
        # bypass submit()'s guard the way a direct enqueue would
        req = Request(uid=999, prompt=np.arange(40) % 50, max_tokens=20)
        eng.scheduler.submit(req)
        done = eng.run_until_drained()
        assert any(r.uid == 999 and r.done and r.generated == []
                   for r in done)
