"""Paged KV cache + prefix reuse + chunked prefill (DESIGN.md §14).

Covers the host page pool/prefix registry, the page-table-indirect Pallas
decode kernel (interpret mode), token-identity of the paged engine against
the dense engine (the parity oracle), shared-prefix reuse, chunked
admission, the bucketed-executable cap, scheduler edge cases, and the
suffix-only accounting of prefix hits.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import accounting, energy
from repro.models import transformer as tf_lib
from repro.serve import (PagePool, Request, Scheduler, SchedulerConfig,
                         ServeConfig, ServeEngine, block_tokens,
                         generation_agreement, run_workload)
from repro.serve.pages import ROOT
from repro.serve.engine import _bucket_len


def _cfg(vocab=61):
    return tf_lib.LMConfig(name="t", d_model=48, n_heads=4, n_kv_heads=2,
                           d_ff=96, vocab=vocab, pattern=(tf_lib.BlockSpec(),),
                           repeats=2, remat="none", vocab_pad_multiple=1)


def _params(cfg, seed=0):
    return tf_lib.init_lm(jax.random.PRNGKey(seed), cfg,
                          dtype=jnp.float32).params


def _dense(params, cfg, **kw):
    return ServeEngine(params, cfg, ServeConfig(max_slots=2, max_len=64, **kw))


def _paged(params, cfg, **kw):
    kw.setdefault("page_size", 4)
    return ServeEngine(params, cfg, ServeConfig(max_slots=2, max_len=64,
                                                paged=True, **kw))


RAGGED = [np.arange(30), np.arange(3) + 7, np.arange(21) + 2,
          np.arange(9) + 40]


def _shared_prefix_workload(n=6, prefix_len=12, tail_len=4):
    sys_prompt = np.arange(prefix_len) + 20
    return [np.concatenate([sys_prompt, np.arange(tail_len) + 3 * i])
            for i in range(n)]


# -----------------------------------------------------------------------------
# Host page pool + prefix registry
# -----------------------------------------------------------------------------

class TestPagePool:
    def test_alloc_release_lifecycle(self):
        pool = PagePool(4, page_size=8)
        a = pool.alloc(3)
        assert len(a) == 3 and all(pool.refcount(p) == 1 for p in a)
        assert pool.available == 1 and pool.live == 3
        pool.release_all(a)
        assert pool.available == 4 and pool.live == 0
        # unpublished pages return to the free list, not the LRU park
        assert pool.cached_pages() == ()

    def test_alloc_failure_defers(self):
        pool = PagePool(2, page_size=4)
        assert pool.alloc(3) is None
        assert pool.stats.alloc_failures == 1
        assert pool.available == 2            # nothing leaked

    def _publish_chain(self, pool, pages, blocks):
        parent = ROOT
        for p, b in zip(pages, blocks):
            parent = pool.publish(p, parent, b)

    def test_duplicate_chain_converges_on_canonical(self):
        """Two slots that computed the same prefix concurrently publish
        the SAME chain: the loser's pages stay unpublished (freed, not
        parked) and the registry holds one reachable chain, not a shadow
        chain keyed on unreachable parents."""
        pool = PagePool(8, page_size=2)
        blocks = block_tokens([1, 2, 3, 4, 5, 6], 2)
        a, b = pool.alloc(3), pool.alloc(3)
        self._publish_chain(pool, a, blocks)
        self._publish_chain(pool, b, blocks)     # first writer wins
        assert set(pool.cached_pages()) == set(a)
        pool.release_all(a)
        pool.release_all(b)
        # the loser's pages went back to the free list; canonical chain
        # parks in LRU and stays fully hittable
        assert set(pool.cached_pages()) == set(a)
        assert pool.lookup(blocks) == a
        pool.release_all(a)

    def test_publish_lookup_longest_chain(self):
        pool = PagePool(8, page_size=2)
        toks = np.arange(8)
        blocks = block_tokens(toks, 2)
        pages = pool.alloc(4)
        self._publish_chain(pool, pages, blocks)
        # a prompt sharing 3 blocks then diverging hits exactly 3
        other = np.concatenate([toks[:6], [99, 98]])
        hits = pool.lookup(block_tokens(other, 2))
        assert hits == pages[:3]
        assert all(pool.refcount(p) == 2 for p in hits)   # retained
        assert pool.stats.hit_blocks == 3
        assert pool.stats.missed_blocks == 1

    def test_lru_eviction_unpublishes(self):
        pool = PagePool(2, page_size=2)
        blocks = block_tokens(np.arange(4), 2)
        pages = pool.alloc(2)
        self._publish_chain(pool, pages, blocks)
        pool.release_all(pages)               # park in LRU, still hittable
        assert set(pool.cached_pages()) == set(pages)
        fresh = pool.alloc(1)                 # free list dry -> evict LRU
        assert fresh == [pages[0]]            # least-recently-used first
        assert pool.stats.evicted_blocks == 1
        # the evicted block's key is gone; the chain now misses at block 0
        assert pool.lookup(blocks) == []

    def test_block_tokens_and_chain_matching(self):
        b1 = block_tokens([1, 2, 3, 4, 5], 2)
        assert b1 == [(1, 2), (3, 4)]         # trailing partial dropped
        # matching is CHAINED through parent pages: an earlier-block
        # mismatch breaks the whole chain even if a later block's tokens
        # are identical
        pool = PagePool(8, page_size=2)
        pages = pool.alloc(2)
        self._publish_chain(pool, pages, b1)
        assert pool.lookup(block_tokens([9, 2, 3, 4], 2)) == []
        assert pool.lookup(block_tokens([1, 2, 3, 4], 2)) == pages

    def test_recycled_parent_invalidates_child_keys(self):
        """Evicting/recycling a parent page cascade-unpublishes children:
        a recycled page id holding NEW content must never certify an old
        child chain (the stale-chain false-hit hazard)."""
        pool = PagePool(2, page_size=2)
        pages = pool.alloc(2)
        self._publish_chain(pool, pages, [(1, 2), (3, 4)])
        pool.release_all(pages)
        # evict the parent and republish it with different content
        (recycled,) = pool.alloc(1)
        assert recycled == pages[0]
        pool.publish(recycled, ROOT, (7, 8))
        # [7, 8, 3, 4]: block 0 hits the recycled page, but the old child
        # key (parent=pages[0], (3, 4)) was computed under [1, 2] context
        # and must NOT match
        hits = pool.lookup([(7, 8), (3, 4)])
        assert hits == [recycled]


# -----------------------------------------------------------------------------
# Paged decode kernel (interpret mode) vs gather oracle
# -----------------------------------------------------------------------------

class TestPagedKernel:
    def _oracle(self, q, kpool, vpool, pt, lens, window):
        from repro.models import layers
        b, nb = pt.shape
        ps = kpool.shape[1]
        kg = kpool[pt].reshape(b, nb * ps, *kpool.shape[2:])
        vg = vpool[pt].reshape(b, nb * ps, *vpool.shape[2:])
        tags = jnp.where(jnp.arange(nb * ps)[None] < lens[:, None],
                         jnp.arange(nb * ps)[None], -1)
        mask = layers.attention_mask((lens - 1)[:, None], tags, causal=True,
                                     window=window)
        mask &= (tags >= 0)[:, None, :]
        return layers.sdpa(q, kg, vg, mask, 0.25)[:, 0]

    def test_matches_gather_oracle_ragged_lengths(self):
        from repro.kernels import ops as kops
        rng = np.random.default_rng(3)
        b, ps, nb, h, hkv, d, P = 4, 8, 3, 4, 2, 16, 10
        q = jnp.asarray(rng.standard_normal((b, 1, h, d)), jnp.float32)
        kpool = jnp.asarray(rng.standard_normal((P + 1, ps, hkv, d)),
                            jnp.float32)
        vpool = jnp.asarray(rng.standard_normal((P + 1, ps, hkv, d)),
                            jnp.float32)
        pt = jnp.asarray(rng.integers(0, P, size=(b, nb)), jnp.int32)
        lens = jnp.asarray([24, 10, 0, 1], jnp.int32)
        for window in (-1, 6):
            got = kops.paged_decode_attention(q[:, 0], kpool, vpool, pt,
                                              lens, scale=0.25,
                                              window=window, interpret=True)
            want = self._oracle(q, kpool, vpool, pt, lens, window)
            live = np.asarray(lens) > 0
            err = np.abs(np.asarray(got)[live] - np.asarray(want)[live]).max()
            assert err < 1e-5, (window, err)
            # dead slots return exactly zero
            assert np.abs(np.asarray(got)[~live]).max() == 0.0

    def test_int8_kv_mode_matches_dequant_oracle(self):
        from repro.kernels import ops as kops
        from repro.quant import int8 as int8_lib
        rng = np.random.default_rng(5)
        b, ps, nb, h, hkv, d, P = 3, 8, 2, 4, 2, 16, 6
        q = jnp.asarray(rng.standard_normal((b, 1, h, d)), jnp.float32)
        kpool = jnp.asarray(rng.standard_normal((P + 1, ps, hkv, d)),
                            jnp.float32)
        vpool = jnp.asarray(rng.standard_normal((P + 1, ps, hkv, d)),
                            jnp.float32)
        kq, ks = int8_lib.quantize_rowwise(kpool)
        vq, vs = int8_lib.quantize_rowwise(vpool)
        pt = jnp.asarray(rng.integers(0, P, size=(b, nb)), jnp.int32)
        lens = jnp.asarray([16, 5, 9], jnp.int32)
        got = kops.paged_decode_attention(q[:, 0], kq, vq, pt, lens,
                                          scale=0.25, interpret=True,
                                          k_scale=ks, v_scale=vs)
        kd = int8_lib.dequantize_rowwise(kq, ks, dtype=jnp.float32)
        vd = int8_lib.dequantize_rowwise(vq, vs, dtype=jnp.float32)
        want = self._oracle(q, kd, vd, pt, lens, -1)
        assert np.abs(np.asarray(got) - np.asarray(want)).max() < 1e-5


# -----------------------------------------------------------------------------
# Engine token identity vs the dense parity oracle
# -----------------------------------------------------------------------------

class TestPagedIdentity:
    def test_non_shared_token_identical(self):
        """Acceptance oracle: the paged engine is token-identical to the
        dense engine on a workload with no shared prefixes."""
        cfg = _cfg()
        params = _params(cfg)
        got = run_workload(_paged(params, cfg), RAGGED, max_tokens=6)
        want = run_workload(_dense(params, cfg), RAGGED, max_tokens=6)
        assert generation_agreement(got, want)["identical"] == 1.0

    def test_decode_kernel_token_identical(self):
        cfg = _cfg()
        params = _params(cfg)
        prompts = [np.arange(4), np.arange(3) + 7]
        got = run_workload(
            ServeEngine(params, cfg, ServeConfig(max_slots=2, max_len=16,
                                                 paged=True, page_size=4,
                                                 decode_kernel=True)),
            prompts, max_tokens=3)
        want = run_workload(
            ServeEngine(params, cfg, ServeConfig(max_slots=2, max_len=16)),
            prompts, max_tokens=3)
        assert generation_agreement(got, want)["identical"] == 1.0

    def test_chunked_prefill_token_identical(self):
        """Chunked admission (long prompts spread over ticks, interleaved
        with decode) must not change a single token."""
        cfg = _cfg()
        params = _params(cfg)
        got = run_workload(_paged(params, cfg, prefill_chunk=8), RAGGED,
                           max_tokens=6)
        want = run_workload(_dense(params, cfg), RAGGED, max_tokens=6)
        assert generation_agreement(got, want)["identical"] == 1.0

    def test_int8_paged_token_identical_to_int8_dense(self):
        cfg = _cfg()
        params = _params(cfg)
        got = run_workload(_paged(params, cfg, quant="int8",
                                  prefill_chunk=8), RAGGED, max_tokens=5)
        want = run_workload(_dense(params, cfg, quant="int8"), RAGGED,
                            max_tokens=5)
        assert generation_agreement(got, want)["identical"] == 1.0

    def test_sampling_deterministic_given_seed(self):
        """The (engine seed, request uid) sampling invariant survives the
        paged path — chunk count and slot placement don't leak into RNG."""
        cfg = _cfg()
        params = _params(cfg)

        def run(chunk):
            eng = _paged(params, cfg, prefill_chunk=chunk, seed=0)
            for i, p in enumerate(RAGGED):
                eng.submit(p, max_tokens=5, temperature=0.7)
            return {r.uid: tuple(r.generated)
                    for r in eng.run_until_drained()}

        assert run(0) == run(8)

    def test_paged_rejected_for_ssd(self):
        from repro.models import ssd as ssd_lib
        cfg = tf_lib.LMConfig(
            name="ssd", d_model=32, n_heads=2, n_kv_heads=2, d_ff=64,
            vocab=31, pattern=(tf_lib.BlockSpec(kind="ssd", has_ffn=False),),
            repeats=1, remat="none", vocab_pad_multiple=1,
            ssd_cfg=ssd_lib.SSDConfig(d_model=32, d_state=8, head_dim=16))
        with pytest.raises(NotImplementedError):
            ServeEngine({}, cfg, ServeConfig(max_slots=1, paged=True))


# -----------------------------------------------------------------------------
# Prefix cache: reuse, quality bound, capacity
# -----------------------------------------------------------------------------

class TestPrefixReuse:
    def test_shared_prefix_fp32_agreement_and_savings(self):
        """>= 99% token agreement on a shared-prefix workload, with
        prefix hits reported and prefill tokens strictly reduced."""
        cfg = _cfg()
        params = _params(cfg)
        work = _shared_prefix_workload()
        paged = _paged(params, cfg)
        got = run_workload(paged, work, max_tokens=5)
        want = run_workload(_dense(params, cfg), work, max_tokens=5)
        assert generation_agreement(got, want)["agreement"] >= 0.99
        s = paged.summary()
        assert s["prefix_hit_tokens"] > 0
        assert s["prefix_hit_rate"] > 0.3
        assert s["prefill_tokens"] < sum(len(p) for p in work)

    def test_shared_prefix_int8_agreement(self):
        cfg = _cfg()
        params = _params(cfg)
        work = _shared_prefix_workload()
        paged = _paged(params, cfg, quant="int8")
        got = run_workload(paged, work, max_tokens=5)
        want = run_workload(_dense(params, cfg, quant="int8"), work,
                            max_tokens=5)
        assert generation_agreement(got, want)["agreement"] >= 0.99
        assert paged.summary()["prefix_hit_tokens"] > 0

    def test_fully_cached_prompt_recomputes_last_block(self):
        """A 100%-cached prompt must still run >= 1 suffix token (the
        sampling logits) — and stay token-identical across both runs."""
        cfg = _cfg()
        params = _params(cfg)
        prompt = np.arange(8)                 # 2 full pages of 4
        eng = _paged(params, cfg)
        first = run_workload(eng, [prompt], max_tokens=4)
        second = run_workload(eng, [prompt], max_tokens=4)
        assert list(first.values()) == list(second.values())
        # second admission hit one block (4 tokens), recomputed the other
        assert eng.summary()["prefix_hit_tokens"] == 4

    def test_oversized_request_rejected_at_submit(self):
        """A request whose worst-case page demand exceeds the whole pool
        can never be admitted — submit() must reject it instead of letting
        admission livelock behind an un-fittable head-of-line request."""
        cfg = _cfg()
        params = _params(cfg)
        eng = _paged(params, cfg, num_pages=4)    # 16-token capacity
        with pytest.raises(ValueError, match="pages"):
            eng.submit(np.arange(20), max_tokens=8)
        # a fitting request still goes through
        eng.submit(np.arange(8), max_tokens=4)
        assert len(eng.run_until_drained()) == 1

    def test_tiny_pool_defers_admission_and_drains(self):
        """A pool too small for concurrent occupancy serializes admissions
        by deferral (alloc-aware select) without corrupting any stream."""
        cfg = _cfg()
        params = _params(cfg)
        prompts = [np.arange(12), np.arange(9) + 2, np.arange(7) + 11]
        got = run_workload(_paged(params, cfg, num_pages=5), prompts,
                           max_tokens=5)
        want = run_workload(_dense(params, cfg), prompts, max_tokens=5)
        assert generation_agreement(got, want)["identical"] == 1.0

    def test_prefix_survives_under_pool_pressure(self):
        """Cached prefix pages park in LRU and stay hittable while
        capacity allows; eviction (when forced) never corrupts streams."""
        cfg = _cfg()
        params = _params(cfg)
        work = _shared_prefix_workload(n=4, prefix_len=8, tail_len=1)
        paged = _paged(params, cfg, num_pages=6)
        got = run_workload(paged, work, max_tokens=5)
        want = run_workload(_dense(params, cfg), work, max_tokens=5)
        assert generation_agreement(got, want)["agreement"] >= 0.99
        assert paged.summary()["prefix_hit_tokens"] > 0


# -----------------------------------------------------------------------------
# Bucketed-executable cap + chunk steady state (satellite: compile churn)
# -----------------------------------------------------------------------------

class TestBucketCap:
    def test_bucket_len_capped(self):
        assert _bucket_len(3) == 4
        assert _bucket_len(9) == 16
        assert _bucket_len(40, cap=48) == 48      # not 64
        assert _bucket_len(5, cap=48) == 8
        assert _bucket_len(100, cap=8) == 8

    def test_dense_bucket_capped_at_max_len(self):
        """A prompt between the last pow2 bucket and max_len compiles the
        max_len bucket, not the next pow2 — the executable cache is bounded
        by the configured max prompt length."""
        cfg = _cfg()
        params = _params(cfg)
        eng = ServeEngine(params, cfg, ServeConfig(max_slots=1, max_len=48))
        eng.submit(np.arange(40), max_tokens=2)
        eng.run_until_drained()
        assert set(eng.admit_trace_counts) == {48}
        assert all(v == 1 for v in eng.admit_trace_counts.values())

    def test_chunked_prefill_single_bucket_steady_state(self):
        """With chunked prefill every admission call is at most chunk wide:
        one chunk-size bucket is the steady state no matter how prompt
        lengths vary (the regression the pow2 ladder used to cause)."""
        cfg = _cfg()
        params = _params(cfg)
        eng = _paged(params, cfg, prefill_chunk=8)
        # distinct content (no accidental prefix sharing: a prefix hit
        # shrinks the suffix below the chunk, which is a *different*,
        # correct reason for a smaller bucket); remainders all bucket to 8
        for i, n in enumerate((30, 21, 13, 29, 22)):
            eng.submit(np.arange(n) + 7 * i + 1, max_tokens=2)
        done = eng.run_until_drained()
        assert len(done) == 5
        assert set(eng.admit_trace_counts) == {8}
        assert eng.admit_trace_counts[8] == 1  # traced exactly once


# -----------------------------------------------------------------------------
# Scheduler edge cases (satellite: select/requeue_front)
# -----------------------------------------------------------------------------

def _reqs(lengths):
    return [Request(uid, np.arange(n)) for uid, n in enumerate(lengths, 1)]


class TestSchedulerEdges:
    def test_partial_fill_preserves_fifo_order(self):
        sched = Scheduler(SchedulerConfig(policy="fifo"))
        for r in _reqs([3, 9, 5, 7]):
            sched.submit(r)
        assert [r.uid for r in sched.select(2)] == [1, 2]
        # the remaining queue keeps arrival order
        assert [r.uid for r in sched.pending] == [3, 4]
        assert [r.uid for r in sched.select(5)] == [3, 4]

    def test_fifo_fits_is_head_of_line(self):
        """FIFO stops at the first non-fitting request — no overtaking."""
        sched = Scheduler(SchedulerConfig(policy="fifo"))
        for r in _reqs([9, 3]):
            sched.submit(r)
        picked = sched.select(2, fits=lambda r: len(r.prompt) < 5)
        assert picked == []                   # head doesn't fit -> nothing
        assert [r.uid for r in sched.pending] == [1, 2]

    def test_longest_prompt_skips_non_fitting(self):
        sched = Scheduler(SchedulerConfig(policy="longest_prompt"))
        for r in _reqs([3, 9, 5]):
            sched.submit(r)
        picked = sched.select(2, fits=lambda r: len(r.prompt) < 6)
        assert [len(r.prompt) for r in picked] == [5, 3]
        assert [r.uid for r in sched.pending] == [2]

    def test_requeue_front_restores_selection_order(self):
        sched = Scheduler(SchedulerConfig(policy="fifo"))
        for r in _reqs([3, 9, 5]):
            sched.submit(r)
        picked = sched.select(2)
        sched.requeue_front(picked)
        assert [r.uid for r in sched.pending] == [1, 2, 3]

    def test_longest_prompt_stable_under_requeue(self):
        """Equal-length prompts keep arrival order across repeated
        select/requeue cycles (stable sort + front requeue)."""
        sched = Scheduler(SchedulerConfig(policy="longest_prompt"))
        for r in _reqs([5, 5, 5, 7]):
            sched.submit(r)
        for _ in range(3):
            picked = sched.select(3)
            assert [r.uid for r in picked] == [4, 1, 2]
            sched.requeue_front(picked)
        assert [r.uid for r in sched.pending] == [4, 1, 2, 3]

    def test_paged_engine_with_longest_prompt_policy(self):
        cfg = _cfg()
        params = _params(cfg)
        prompts = [np.arange(3), np.arange(9) + 1, np.arange(6) + 4]
        paged = ServeEngine(
            params, cfg,
            ServeConfig(max_slots=2, max_len=64, paged=True, page_size=4),
            scheduler=Scheduler(SchedulerConfig(policy="longest_prompt")))
        dense = ServeEngine(
            params, cfg, ServeConfig(max_slots=2, max_len=64),
            scheduler=Scheduler(SchedulerConfig(policy="longest_prompt")))
        got = run_workload(paged, prompts, max_tokens=4)
        want = run_workload(dense, prompts, max_tokens=4)
        assert generation_agreement(got, want)["identical"] == 1.0


# -----------------------------------------------------------------------------
# Accounting: a 75% prefix hit bills only the suffix (satellite)
# -----------------------------------------------------------------------------

class TestPrefixAccounting:
    def test_hit_admission_bills_suffix_only(self):
        """Hand-computed traffic/compute for an admission with a 75%
        prefix hit: 16-token prompt, 12 tokens (3 pages of 4) cached."""
        cfg = _cfg()
        params = _params(cfg)
        ps = 4
        eng = ServeEngine(params, cfg,
                          ServeConfig(max_slots=1, max_len=64, paged=True,
                                      page_size=ps))
        warm = np.arange(16)
        run_workload(eng, [warm], max_tokens=2)     # publishes 4 blocks
        acct = accounting.CarbonAccountant(accounting.AccountantConfig(
            device="tpu_v5e", n_devices=1, grid_mix="NY"))
        eng.accountant = acct
        eng.metrics_log = []
        # same first 12 tokens, distinct last 4 -> 3-block (75%) hit
        probe = np.concatenate([warm[:12], [50, 51, 52, 53]])
        eng.submit(probe, max_tokens=2)
        eng.step()                                   # the admission tick
        m = eng.metrics_log[0]
        assert m.prefix_hit_tokens == 12
        assert m.prefill_tokens == 4                 # suffix only

        # hand-computed KV payload: k+v, n_layers x kv_heads x head_dim,
        # fp32 -> 2 * 2 * 2 * 12 * 4 = 768 bytes per cached token
        token_bytes = 2 * cfg.n_layers * cfg.n_kv_heads * 12 * 4
        assert eng._kv_token_bytes == token_bytes
        assert m.saved_bytes == token_bytes * 12     # 12 un-written tokens

        # FLOPs: matmul weights stream per computed token; causal attention
        # pays end^2 - start^2 = 16^2 - 12^2 (the hit's 12^2 is saved).
        # The same step also runs the first decode tick for the activated
        # slot: one token at live context 16 + 1.
        elems = eng._matmul_elems
        attn_dims = cfg.n_heads * 12
        n_attn = cfg.n_layers
        want_flops = (2.0 * elems * 4
                      + 2.0 * n_attn * attn_dims * (16 ** 2 - 12 ** 2)
                      + 2.0 * elems + 4.0 * n_attn * attn_dims * 17)
        assert m.flops == pytest.approx(want_flops)
        want_saved = (2.0 * elems * 12
                      + 2.0 * n_attn * attn_dims * 12 ** 2)
        assert m.saved_flops == pytest.approx(want_saved)
        # admission KV traffic: the XLA extend path materializes the
        # WHOLE page table per chunk (nslots * nb * ps tokens — §16 bills
        # what actually moves; the kernel path bills page-granular
        # windows), plus writing the 4 new tokens
        gather = token_bytes * 1 * eng._blocks_per_slot * ps
        assert m.prefill_gather_bytes == pytest.approx(gather)
        tick_read = token_bytes * (16 + 1)           # decode part of the tick
        assert m.kv_bytes == pytest.approx(gather + token_bytes * 4
                                           + tick_read)

        # the accountant surfaces the saved DRAM joules + hit rate
        rep = acct.report()
        assert rep["prefix_hit_tokens"] == 12
        assert rep["prefix_hit_rate"] == pytest.approx(12 / 16)
        assert rep["saved_bytes"] == m.saved_bytes
        assert rep["saved_dram_j"] == pytest.approx(
            energy.dram_energy_j(m.saved_bytes))
        assert rep["saved_dram_j"] > 0

    def test_no_hit_admission_books_no_savings(self):
        cfg = _cfg()
        params = _params(cfg)
        acct = accounting.CarbonAccountant(accounting.AccountantConfig(
            device="tpu_v5e", n_devices=1, grid_mix="NY"))
        eng = _paged(params, cfg)
        eng.accountant = acct
        run_workload(eng, [np.arange(9)], max_tokens=3)
        rep = acct.report()
        assert rep["prefix_hit_tokens"] == 0
        assert rep["saved_bytes"] == 0.0 and rep["saved_dram_j"] == 0.0
        assert rep["prefix_hit_rate"] == 0.0
