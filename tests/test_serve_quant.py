"""Quantized serving fast path: int8 weights + int8 KV cache, end to end
(DESIGN.md §12) — plus the per-bucket admit executable cache."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import accounting
from repro.models import transformer as tf_lib
from repro.serve import ServeConfig, ServeEngine, token_agreement


def _cfg(vocab=61):
    return tf_lib.LMConfig(name="t", d_model=48, n_heads=4, n_kv_heads=2,
                           d_ff=96, vocab=vocab, pattern=(tf_lib.BlockSpec(),),
                           repeats=2, remat="none", vocab_pad_multiple=1)


def _params(cfg, seed=0):
    return tf_lib.init_lm(jax.random.PRNGKey(seed), cfg,
                          dtype=jnp.float32).params


def _reference_greedy_int8(qparams, qcfg, prompt, n, max_len=64):
    """Sequential single-sequence decode through the SAME int8 policy —
    the fused engine must be token-identical to it."""
    lp, cc = tf_lib.prefill(qparams, qcfg, jnp.asarray(prompt[None]),
                            max_len=max_len, cache_dtype=jnp.float32)
    out = [int(jnp.argmax(lp[0, -1]))]
    pos = len(prompt)
    for _ in range(n - 1):
        lg, cc = tf_lib.decode_step(qparams, qcfg, jnp.asarray([[out[-1]]]),
                                    jnp.asarray(pos), cc)
        out.append(int(jnp.argmax(lg[0, 0])))
        pos += 1
    return out


class TestInt8Engine:
    def test_greedy_identity_vs_sequential_int8(self):
        """Quantized prefill scatter + fused int8 tick == sequential int8
        decode, token for token, across ragged prompt lengths."""
        cfg = _cfg()
        params = _params(cfg)
        eng = ServeEngine(params, cfg, ServeConfig(max_slots=2, max_len=64,
                                                   quant="int8"))
        qparams, qcfg = eng.params, eng.cfg
        prompts = [np.arange(5), np.arange(3) + 7, np.arange(9) + 2]
        for p in prompts:
            eng.submit(p, max_tokens=6)
        done = sorted(eng.run_until_drained(), key=lambda r: r.uid)
        for r, p in zip(done, prompts):
            assert r.generated == _reference_greedy_int8(qparams, qcfg, p,
                                                         6), r.uid

    def test_cache_is_int8_and_smaller(self):
        cfg = _cfg()
        params = _params(cfg)
        eng = ServeEngine(params, cfg, ServeConfig(max_slots=2, max_len=32,
                                                   quant="int8"))
        kv = eng.state.caches["pat0"]["kv"]
        assert kv.k.dtype == jnp.int8 and kv.v.dtype == jnp.int8
        sc = eng.state.caches["pat0"]["kv_scale"]
        assert sc.k.dtype == jnp.float32
        # acceptance: >= 1.5x fewer resident KV bytes than the bf16 cache
        bf16 = ServeEngine(params, cfg,
                           ServeConfig(max_slots=2, max_len=32,
                                       cache_dtype=jnp.bfloat16))
        assert bf16.kv_cache_bytes / eng.kv_cache_bytes >= 1.5
        # int8 weight tree beats the fp32 one by ~4x (scales are small)
        assert bf16.weight_bytes / eng.weight_bytes > 2.0

    def test_decode_kernel_engine_token_identical(self):
        """Int8 engine routed through the Pallas kernels (interpret mode on
        CPU: int8 decode attention + fused int8 matmul) matches the XLA
        dequant path token for token."""
        cfg = _cfg()
        params = _params(cfg)
        xla = ServeEngine(params, cfg, ServeConfig(max_slots=2, max_len=16,
                                                   quant="int8"))
        ker = ServeEngine(params, cfg, ServeConfig(max_slots=2, max_len=16,
                                                   quant="int8",
                                                   decode_kernel=True))
        prompts = [np.arange(4), np.arange(3) + 7]
        for p in prompts:
            xla.submit(p, max_tokens=3)
            ker.submit(p, max_tokens=3)
        got = {r.uid: r.generated for r in ker.run_until_drained()}
        want = {r.uid: r.generated for r in xla.run_until_drained()}
        assert got == want

    def test_agreement_vs_full_precision_reference(self):
        """Acceptance metric: >= 99% greedy-token agreement with the
        full-precision oracle over >= 500 teacher-forced decoded tokens."""
        cfg = _cfg()
        params = _params(cfg)
        prompts = np.random.default_rng(0).integers(0, 61, size=(25, 8))
        res = token_agreement(params, cfg, prompts, n_tokens=24)
        assert res["tokens"] >= 500
        assert res["agreement"] >= 0.99, res
        assert res["max_logit_gap"] < 1.0, res

    def test_modeled_j_per_token_drops(self):
        """The per-byte DRAM term (core.energy) makes the int8 byte
        reduction visible as a J/token drop on the same workload."""
        cfg = _cfg()
        params = _params(cfg)
        reports = {}
        for quant in ("none", "int8"):
            acct = accounting.CarbonAccountant(accounting.AccountantConfig(
                device="tpu_v5e", n_devices=1, grid_mix="NY"))
            eng = ServeEngine(params, cfg,
                              ServeConfig(max_slots=2, max_len=32,
                                          quant=quant), accountant=acct)
            for i in range(4):
                eng.submit(np.arange(4) + i, max_tokens=4)
            eng.run_until_drained()
            reports[quant] = acct.report()
        fp, q = reports["none"], reports["int8"]
        assert q["bytes_moved"] < fp["bytes_moved"] / 1.5
        assert q["modeled_j_per_token"] < fp["modeled_j_per_token"]
        # FLOPs model is storage-dtype independent: same tokens, same flops
        assert q["modeled_flops"] == pytest.approx(fp["modeled_flops"])

    def test_unknown_quant_mode_rejected(self):
        cfg = _cfg()
        with pytest.raises(ValueError):
            ServeEngine(_params(cfg), cfg,
                        ServeConfig(max_slots=1, quant="fp4"))


class TestQuantizeLM:
    def test_structure_and_passthrough(self):
        cfg = _cfg()
        params = _params(cfg)
        qp = tf_lib.quantize_lm(params)
        assert qp["embed"]["w"].dtype == jnp.float32       # never quantized
        assert qp["final_norm"]["scale"].dtype == jnp.float32
        leaf = qp["pat0"]["attn"]["wq"]
        assert leaf["q8"].dtype == jnp.int8
        # stacked per-layer, per-channel scales: (repeats, 1, heads, head_dim)
        assert leaf["s8"].shape == (2, 1, 4, 12)
        mlp_out = qp["pat0"]["mlp"]["w_out"]
        assert mlp_out["s8"].shape == (2, 1, 48)

    def test_idempotent(self):
        params = _params(_cfg())
        qp = tf_lib.quantize_lm(params)
        qp2 = tf_lib.quantize_lm(qp)
        assert qp2["pat0"]["attn"]["wq"]["q8"] is qp["pat0"]["attn"]["wq"]["q8"]


class TestAdmitBucketCache:
    def test_one_trace_per_bucket(self):
        """Admission compiles exactly once per prompt-length bucket no
        matter how many admissions hit the bucket (no rebuild churn)."""
        cfg = _cfg()
        params = _params(cfg)
        eng = ServeEngine(params, cfg, ServeConfig(max_slots=1, max_len=64))
        for n in (3, 3, 9, 9, 3):          # buckets: 4, 4, 16, 16, 4
            eng.submit(np.arange(n), max_tokens=2)
        done = eng.run_until_drained()
        assert len(done) == 5
        assert sum(m.admitted for m in eng.metrics_log) == 5
        assert eng.admit_trace_counts == {4: 1, 16: 1}
        assert set(eng._admit_fns) == {4, 16}
