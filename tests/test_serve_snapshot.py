"""Durability tier (DESIGN.md §19): crash-consistent engine snapshots,
write-ahead journal replay, token-identical warm restart.

The load-bearing invariant, locked across serving modes (paged fp32/int8,
speculative, COW n-best, chunked mid-prefill): an engine snapshotted at an
ARBITRARY tick and restored into a fresh process continues every stream —
and every deterministic summary counter — exactly as the uninterrupted run
would have. Plus: the integrity gates refuse corrupted or inconsistent
snapshots loudly, the journal survives torn tails, process_kill
chaos round-trips through restore(), durability counters 0.0-guard on
checkpoint-free engines, and bench JSON emission is kill-atomic.
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.checkpoint.manager import tree_checksum
from repro.core import accounting
from repro.models import transformer as tf_lib
from repro.serve import (FaultEvent, FaultPlan, Journal, ProcessKilled,
                         ServeConfig, ServeEngine)


def _cfg():
    return tf_lib.LMConfig(name="t", d_model=48, n_heads=4, n_kv_heads=2,
                           d_ff=96, vocab=61, pattern=(tf_lib.BlockSpec(),),
                           repeats=2, remat="none", vocab_pad_multiple=1)


_MODEL = []


def _model():
    if not _MODEL:
        cfg = _cfg()
        params = tf_lib.init_lm(jax.random.PRNGKey(0), cfg,
                                dtype=jnp.float32).params
        _MODEL.append((cfg, params))
    return _MODEL[0]


@pytest.fixture(scope="module")
def model():
    return _model()


PROMPTS = [np.arange(15), np.arange(11) + 7, np.arange(8) + 30]
LONG_PROMPTS = [np.arange(40) % 50, (np.arange(36) + 3) % 50]

# serving-mode matrix: every mode must snapshot/restore bit-identically.
# Each entry: (ServeConfig overrides, prompts, submit kwargs)
MODES = {
    "plain": (dict(), PROMPTS, dict(max_tokens=8)),
    "int8": (dict(quant="int8"), PROMPTS, dict(max_tokens=8)),
    "spec": (dict(spec_k=2), PROMPTS, dict(max_tokens=8)),
    "nbest": (dict(max_slots=4, num_pages=64, temperature=0.7),
              [np.arange(18), np.arange(12) + 5],
              dict(max_tokens=8, n_best=2)),
    "chunk": (dict(max_len=128, num_pages=80, prefill_chunk=8),
              LONG_PROMPTS, dict(max_tokens=6)),
}

# counters that must match the continuous run exactly after a restore
# (wall-clock and durability channels legitimately differ)
EQUIV_KEYS = ("ticks", "decode_tokens", "prefill_tokens",
              "prefix_hit_tokens", "shed", "quarantined", "forks",
              "cow_copies")


def _scfg(over, **kw):
    base = dict(max_slots=2, max_len=64, paged=True, page_size=4, seed=0)
    base.update(over)
    base.update(kw)
    return ServeConfig(**base)


def _streams(reqs):
    return {r.uid: (list(r.generated),
                    ([[int(t) for t in s] for s in r.nbest]
                     if r.nbest is not None else None)) for r in reqs}


def _submit_all(eng, prompts, sub_kw):
    for p in prompts:
        eng.submit(p, **sub_kw)


_BASELINES = {}


def _baseline(model, mode):
    """Continuous (checkpoint-free) run of a mode, cached per module."""
    if mode not in _BASELINES:
        cfg, params = model
        over, prompts, sub_kw = MODES[mode]
        eng = ServeEngine(params, cfg, _scfg(over))
        _submit_all(eng, prompts, sub_kw)
        done = eng.run_until_drained(max_ticks=400)
        _BASELINES[mode] = (_streams(done), eng.summary())
    return _BASELINES[mode]


def _restore_check(model, mode, n_ticks, tmpdir, interval=2):
    """Run a durable engine ``n_ticks`` ticks, abandon it (simulated
    crash), restore a fresh engine from disk, drain, and assert stream +
    counter equivalence with the continuous run."""
    cfg, params = model
    over, prompts, sub_kw = MODES[mode]
    want, want_s = _baseline(model, mode)
    d = os.path.join(str(tmpdir), f"{mode}_{n_ticks}")
    scfg = _scfg(over, checkpoint_dir=d, checkpoint_interval=interval)
    eng = ServeEngine(params, cfg, scfg)
    _submit_all(eng, prompts, sub_kw)
    for _ in range(n_ticks):
        eng.step()
        if not len(eng.scheduler) and all(r is None for r in eng.slot_req):
            break                      # drained before the crash tick
    # crash: the half-run engine object is simply dropped
    eng2 = ServeEngine(params, cfg, scfg)
    recovered = eng2.restore()
    done = eng2.run_until_drained(max_ticks=400)
    got = _streams(recovered)
    got.update(_streams(done))          # at-least-once: dedupe by uid
    assert got == want, (mode, n_ticks)
    s = eng2.summary()
    for key in EQUIV_KEYS:
        if key in want_s:
            assert s[key] == want_s[key], (mode, n_ticks, key)
    assert eng2.pool.audit() == [] and eng2.pool.live == 0


# -----------------------------------------------------------------------------
# Tentpole: snapshot/restore stream equivalence across serving modes
# -----------------------------------------------------------------------------

class TestRestoreEquivalence:
    @pytest.mark.parametrize("mode", sorted(MODES))
    def test_mid_run_restore_matches_continuous(self, model, mode, tmpdir):
        _restore_check(model, mode, n_ticks=3, tmpdir=tmpdir)

    def test_restore_before_any_snapshot_replays_journal_only(
            self, model, tmpdir):
        """Crash before the first snapshot lands: restore finds no
        checkpoint and rebuilds purely from the fsync'd journal."""
        _restore_check(model, "plain", n_ticks=1, tmpdir=tmpdir,
                       interval=100)

    def test_restore_journal_only_mode(self, model, tmpdir):
        """checkpoint_interval=0: journal-only durability (every tick
        replayed from tick 0)."""
        _restore_check(model, "plain", n_ticks=4, tmpdir=tmpdir,
                       interval=0)

    def test_restore_after_drain_returns_everything(self, model, tmpdir):
        """Restore of a COMPLETED run reconstructs every finished stream
        (the redelivery path a crashed-after-drain caller reads)."""
        cfg, params = model
        want, _ = _baseline(model, "plain")
        d = os.path.join(str(tmpdir), "drained")
        scfg = _scfg({}, checkpoint_dir=d, checkpoint_interval=2)
        eng = ServeEngine(params, cfg, scfg)
        _submit_all(eng, PROMPTS, dict(max_tokens=8))
        eng.run_until_drained(max_ticks=400)
        eng2 = ServeEngine(params, cfg, scfg)
        got = _streams(eng2.restore())
        assert got == want
        assert eng2.run_until_drained(max_ticks=10) == []

    @settings(max_examples=8, deadline=None)
    @given(st.integers(1, 10), st.sampled_from(sorted(MODES)),
           st.sampled_from((1, 2, 3)))
    def test_restore_at_arbitrary_tick(self, n_ticks, mode, interval):
        """Property: for ANY (crash tick, serving mode, snapshot cadence),
        restore + drain is stream- and counter-identical to never
        crashing. The cached baselines make each example one short drain."""
        import tempfile
        _restore_check(_model(), mode, n_ticks,
                       tempfile.mkdtemp(prefix="snap_hyp."),
                       interval=interval)

    def test_restore_requires_fresh_engine(self, model, tmpdir):
        cfg, params = model
        scfg = _scfg({}, checkpoint_dir=str(tmpdir), checkpoint_interval=2)
        eng = ServeEngine(params, cfg, scfg)
        eng.submit(PROMPTS[0], max_tokens=4)
        eng.step()
        with pytest.raises(RuntimeError, match="fresh engine"):
            eng.restore()

    def test_restore_requires_checkpoint_dir(self, model):
        cfg, params = model
        eng = ServeEngine(params, cfg, _scfg({}))
        with pytest.raises(RuntimeError, match="checkpoint_dir"):
            eng.restore()


# -----------------------------------------------------------------------------
# process_kill chaos arm: kill, restart, token-identical continuation
# -----------------------------------------------------------------------------

class TestProcessKill:
    def test_kill_restore_drain_identical(self, model, tmpdir):
        cfg, params = model
        want, _ = _baseline(model, "plain")
        d = os.path.join(str(tmpdir), "kill")
        scfg = _scfg(dict(faults=FaultPlan.single("process_kill", tick=7,
                                                  seed=3)),
                     checkpoint_dir=d, checkpoint_interval=2)
        eng = ServeEngine(params, cfg, scfg)
        _submit_all(eng, PROMPTS, dict(max_tokens=8))
        with pytest.raises(ProcessKilled):
            eng.run_until_drained(max_ticks=400)
        assert eng._injector.counts["process_kill"] == 1
        acct = accounting.CarbonAccountant(accounting.AccountantConfig(
            device="tpu_v5e", n_devices=1, grid_mix="NY"))
        eng2 = ServeEngine(params, cfg, scfg, accountant=acct)
        got = _streams(eng2.restore())
        got.update(_streams(eng2.run_until_drained(max_ticks=400)))
        assert got == want
        s = eng2.summary()
        # kill at tick 7, snapshots every 2: the latest snapshot covers
        # ticks 0..5, so replay repeats tick 6 — billed as restore_j
        assert s["replayed_ticks"] == 1
        assert s["restore_j"] > 0.0
        assert s["snapshots_taken"] > 0
        assert s["journal_bytes"] > 0.0
        rep = acct.report()
        assert rep["replayed_ticks"] == 1
        assert rep["restore_j"] > 0.0

    def test_restored_kill_does_not_refire(self, model, tmpdir):
        """The restart carries the same fault plan; a kill at or before
        the restore boundary already happened pre-crash and must not fire
        again (the crash-loop guard). A LATER kill still fires, and a
        second restore survives it too."""
        cfg, params = model
        want, _ = _baseline(model, "plain")
        d = os.path.join(str(tmpdir), "kill2")
        plan = FaultPlan(seed=3, events=(
            FaultEvent(tick=4, kind="process_kill"),
            FaultEvent(tick=8, kind="process_kill")))
        scfg = _scfg(dict(faults=plan), checkpoint_dir=d,
                     checkpoint_interval=2)
        eng = ServeEngine(params, cfg, scfg)
        _submit_all(eng, PROMPTS, dict(max_tokens=8))
        with pytest.raises(ProcessKilled):
            eng.run_until_drained(max_ticks=400)
        eng2 = ServeEngine(params, cfg, scfg)
        got = _streams(eng2.restore())
        with pytest.raises(ProcessKilled):      # tick-8 kill still fires
            eng2.run_until_drained(max_ticks=400)
        eng3 = ServeEngine(params, cfg, scfg)
        got.update(_streams(eng3.restore()))
        got.update(_streams(eng3.run_until_drained(max_ticks=400)))
        assert got == want


# -----------------------------------------------------------------------------
# Integrity gates: corrupted and inconsistent snapshots refuse loudly
# -----------------------------------------------------------------------------

def _latest_ckpt_dir(checkpoint_dir):
    snaps = os.path.join(checkpoint_dir, "snapshots")
    return os.path.join(snaps, sorted(os.listdir(snaps))[-1])


def _durable_run(model, tmpdir, name):
    cfg, params = model
    d = os.path.join(str(tmpdir), name)
    scfg = _scfg({}, checkpoint_dir=d, checkpoint_interval=2)
    eng = ServeEngine(params, cfg, scfg)
    _submit_all(eng, PROMPTS, dict(max_tokens=8))
    for _ in range(5):
        eng.step()
    return scfg, d


class TestIntegrityGates:
    def test_bitflip_in_arrays_refuses(self, model, tmpdir):
        cfg, params = model
        scfg, d = _durable_run(model, tmpdir, "bitrot")
        path = os.path.join(_latest_ckpt_dir(d), "arrays.npz")
        with open(path, "r+b") as f:
            f.seek(os.path.getsize(path) // 2)
            b = f.read(1)
            f.seek(-1, os.SEEK_CUR)
            f.write(bytes([b[0] ^ 0xFF]))
        eng2 = ServeEngine(params, cfg, scfg)
        # the zip layer's CRC or the manifest checksum — either way the
        # corrupt snapshot must never install
        with pytest.raises(Exception):
            eng2.restore()

    def test_tampered_extra_fails_checksum(self, model, tmpdir):
        cfg, params = model
        scfg, d = _durable_run(model, tmpdir, "tamper")
        mpath = os.path.join(_latest_ckpt_dir(d), "manifest.json")
        with open(mpath) as f:
            man = json.load(f)
        man["extra"]["tick_idx"] += 1          # doctored, NOT re-signed
        with open(mpath, "w") as f:
            json.dump(man, f)
        eng2 = ServeEngine(params, cfg, scfg)
        with pytest.raises(RuntimeError, match="integrity check"):
            eng2.restore()

    def test_resigned_inconsistent_snapshot_names_invariant(
            self, model, tmpdir):
        """A tamper that re-signs the checksum gets past the digest — the
        shared refcount/ownership reconciliation (the same checker the
        in-tick audit uses) must still refuse, naming the violation."""
        cfg, params = model
        scfg, d = _durable_run(model, tmpdir, "resign")
        ck = _latest_ckpt_dir(d)
        mpath = os.path.join(ck, "manifest.json")
        with open(mpath) as f:
            man = json.load(f)
        held = next(pages[0] for pages in man["extra"]["slot_pages"]
                    if pages)                  # a page the engine holds
        man["extra"]["pool"]["ref"][held] = 0  # ...that the pool forgets
        arrays = np.load(os.path.join(ck, "arrays.npz"))
        named = [(n, arrays[n]) for n in man["names"]]
        man["checksum"] = tree_checksum(named, man["extra"])
        with open(mpath, "w") as f:
            json.dump(man, f)
        eng2 = ServeEngine(params, cfg, scfg)
        with pytest.raises(RuntimeError,
                           match="consistency check.*pool says"):
            eng2.restore()

    def test_config_fingerprint_mismatch_refuses(self, model, tmpdir):
        cfg, params = model
        _, d = _durable_run(model, tmpdir, "fprint")
        other = _scfg(dict(page_size=8, checkpoint_dir=d,
                           checkpoint_interval=2))
        eng2 = ServeEngine(params, cfg, other)
        with pytest.raises(RuntimeError, match="page_size"):
            eng2.restore()


# -----------------------------------------------------------------------------
# Journal: WAL contract, torn tails, replay divergence
# -----------------------------------------------------------------------------

class TestJournal:
    def test_round_trip_and_seq(self, tmpdir):
        path = os.path.join(str(tmpdir), "j.jsonl")
        j = Journal(path)
        n1 = j.append_submit(uid=1, prompt=[1, 2], max_tokens=4,
                             temperature=None, deadline_ticks=None,
                             n_best=1, tick=0)
        n2 = j.append_tick(tick=0, finished=[[1, [5, 6], None]])
        assert n1 > 0 and n2 > 0
        assert j.bytes_written == n1 + n2
        recs = j.records()
        assert [r["seq"] for r in recs] == [0, 1]
        assert recs[0]["kind"] == "submit" and recs[1]["kind"] == "tick"
        j.close()

    def test_torn_tail_truncated_on_open(self, tmpdir):
        path = os.path.join(str(tmpdir), "j.jsonl")
        j = Journal(path)
        j.append_submit(uid=1, prompt=[1], max_tokens=4, temperature=None,
                        deadline_ticks=None, n_best=1, tick=0)
        j.append_tick(tick=0, finished=[])
        j.close()
        with open(path, "a") as f:
            f.write('{"kind": "tick", "tick": 1, "fini')   # torn write
        j2 = Journal(path)
        recs = j2.records()
        assert len(recs) == 2                  # torn record dropped
        assert j2.seq == 2                     # next seq continues
        with open(path) as f:
            assert f.read().endswith("\n")     # file physically truncated
        j2.close()

    def test_replay_divergence_raises(self, model, tmpdir):
        """A journal whose recorded emissions can't be reproduced (here:
        doctored generated tokens) must refuse — serving silently
        different streams after 'recovery' is the one unforgivable
        failure mode."""
        cfg, params = model
        d = os.path.join(str(tmpdir), "diverge")
        scfg = _scfg({}, checkpoint_dir=d, checkpoint_interval=0)
        eng = ServeEngine(params, cfg, scfg)
        _submit_all(eng, PROMPTS, dict(max_tokens=8))
        eng.run_until_drained(max_ticks=400)
        jpath = os.path.join(d, "journal.jsonl")
        with open(jpath) as f:
            recs = [json.loads(ln) for ln in f]
        for r in recs:
            if r["kind"] == "tick" and r["finished"]:
                r["finished"][0][1][0] ^= 1    # flip one emitted token
                break
        with open(jpath, "w") as f:
            for r in recs:
                f.write(json.dumps(r, sort_keys=True) + "\n")
        eng2 = ServeEngine(params, cfg, scfg)
        with pytest.raises(RuntimeError, match="replay diverged"):
            eng2.restore()


# -----------------------------------------------------------------------------
# Zero-state guards (satellite): durability counters on checkpoint-free runs
# -----------------------------------------------------------------------------

class TestZeroStateGuards:
    def test_engine_summary_durability_zeros(self, model):
        cfg, params = model
        eng = ServeEngine(params, cfg, _scfg({}))
        s = eng.summary()
        for key in ("snapshots_taken", "snapshot_bytes", "journal_bytes",
                    "replayed_ticks", "restore_j", "restore_j_per_token",
                    "durability_write_j"):
            assert s[key] == 0.0, key

    def test_accountant_report_durability_zeros(self):
        acct = accounting.CarbonAccountant(accounting.AccountantConfig(
            device="tpu_v5e", n_devices=1, grid_mix="NY"))
        rep = acct.report()
        for key in ("snapshots_taken", "snapshot_bytes", "journal_bytes",
                    "replayed_ticks", "restore_j", "restore_j_per_token",
                    "durability_write_j"):
            assert rep[key] == 0.0, key

    def test_accountant_state_round_trip(self):
        a = accounting.CarbonAccountant(accounting.AccountantConfig(
            device="tpu_v5e", n_devices=1, grid_mix="NY"))
        a.observe_step(0.5, n_tokens=10)
        a.observe_durability(snapshot_bytes=100.0, journal_bytes=7.0,
                             restore_flops=2.0, restore_bytes=3.0,
                             replayed_ticks=1, snapshots=1)
        b = accounting.CarbonAccountant(accounting.AccountantConfig(
            device="tpu_v5e", n_devices=1, grid_mix="NY"))
        b.load_state(a.state_dict())
        ra, rb = a.report(), b.report()
        for key in ("tokens", "steps", "snapshots_taken", "snapshot_bytes",
                    "journal_bytes", "replayed_ticks", "restore_j",
                    "durability_write_j"):
            assert ra[key] == rb[key], key

    def test_engine_rejects_bad_checkpoint_config(self, model):
        cfg, params = model
        with pytest.raises(ValueError, match="checkpoint_interval"):
            ServeEngine(params, cfg, _scfg(dict(checkpoint_interval=-1)))
        with pytest.raises(ValueError, match="checkpoint_dir"):
            ServeEngine(params, cfg, _scfg(dict(checkpoint_interval=2)))


# -----------------------------------------------------------------------------
# Costing helpers + atomic bench emission (satellites)
# -----------------------------------------------------------------------------

class TestDurabilityCosting:
    def test_expected_replay_ticks(self):
        from repro.models import costing
        assert costing.expected_replay_ticks(0) == 0.0
        assert costing.expected_replay_ticks(1) == 0.0
        assert costing.expected_replay_ticks(5) == 2.0

    def test_overhead_bytes_per_tick_tradeoff(self):
        from repro.models import costing
        # shrinking the interval raises write overhead, lowers replay
        hi = costing.durability_overhead_bytes_per_tick(1000.0, 10.0, 2)
        lo = costing.durability_overhead_bytes_per_tick(1000.0, 10.0, 10)
        assert hi > lo
        assert costing.durability_overhead_bytes_per_tick(
            1000.0, 10.0, 0) == 10.0
        assert (costing.expected_replay_ticks(2)
                < costing.expected_replay_ticks(10))


class TestAtomicBenchWrite:
    def test_mid_write_kill_never_leaves_partial(self, tmpdir):
        import sys
        sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..",
                                        "benchmarks"))
        try:
            from bench_util import atomic_write_json
        finally:
            sys.path.pop(0)
        path = os.path.join(str(tmpdir), "BENCH_x.json")
        atomic_write_json(path, {"ok": 1})
        # a payload that serializes half-way then dies simulates a kill
        # mid-write: the old complete file must survive, no tmp debris
        with pytest.raises(TypeError):
            atomic_write_json(path, {"a": 1, "bad": object()})
        with open(path) as f:
            assert json.load(f) == {"ok": 1}
        assert os.listdir(str(tmpdir)) == ["BENCH_x.json"]
        atomic_write_json(path, {"ok": 2})
        with open(path) as f:
            assert json.load(f) == {"ok": 2}
