"""Speculative multi-token decode on the paged path (DESIGN.md §15) + the
serve-stats correctness sweep that rode along with it.

Covers: the multi-query verify Pallas kernel (interpret mode) vs a gather
oracle, the drafter/accept device policies, temp=0 stream identity of the
speculative engine against the dense greedy engine across fp32/int8/
chunked-prefill/kernel configs and k in {1, 2, 4}, the all-reject and
mid-run-finish edges, the draft-vs-verify energy split, and the stats
regressions (zero-division guards, defer-books-once, oversized-queue drop,
publish-before-release at finish).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import accounting
from repro.models import transformer as tf_lib
from repro.serve import (PagePool, Request, ServeConfig, ServeEngine,
                         generation_agreement, ngram_draft, run_workload,
                         speculative_accept)
from repro.serve import spec as spec_lib
from repro.serve.pages import PoolStats


def _cfg(vocab=61, pad=1):
    return tf_lib.LMConfig(name="t", d_model=48, n_heads=4, n_kv_heads=2,
                           d_ff=96, vocab=vocab,
                           pattern=(tf_lib.BlockSpec(),), repeats=2,
                           remat="none", vocab_pad_multiple=pad)


def _params(cfg, seed=0):
    return tf_lib.init_lm(jax.random.PRNGKey(seed), cfg,
                          dtype=jnp.float32).params


def _dense(params, cfg, **kw):
    return ServeEngine(params, cfg, ServeConfig(max_slots=2, max_len=64,
                                                **kw))


def _spec(params, cfg, k, **kw):
    kw.setdefault("page_size", 4)
    return ServeEngine(params, cfg, ServeConfig(max_slots=2, max_len=64,
                                                paged=True, spec_k=k, **kw))


RAGGED = [np.arange(30), np.arange(3) + 7, np.arange(21) + 2,
          np.arange(9) + 40]


# -----------------------------------------------------------------------------
# Multi-query verify kernel (interpret mode) vs gather oracle
# -----------------------------------------------------------------------------

class TestPagedVerifyKernel:
    def _oracle(self, q, kpool, vpool, pt, lens, window):
        from repro.models import layers
        b, t = q.shape[:2]
        nb = pt.shape[1]
        ps = kpool.shape[1]
        kg = kpool[pt].reshape(b, nb * ps, *kpool.shape[2:])
        vg = vpool[pt].reshape(b, nb * ps, *vpool.shape[2:])
        j_abs = jnp.arange(nb * ps)[None]
        tags = jnp.where(j_abs < lens[:, None], j_abs, -1)
        q_pos = (lens - t)[:, None] + jnp.arange(t)[None]       # (B, T)
        mask = layers.attention_mask(q_pos, tags, causal=True,
                                     window=window)
        mask &= (tags >= 0)[:, None, :]
        return layers.sdpa(q, kg, vg, mask, 0.25)

    def test_matches_gather_oracle_ragged_lengths(self):
        from repro.kernels import ops as kops
        rng = np.random.default_rng(3)
        b, t, ps, nb, h, hkv, d, P = 4, 3, 8, 3, 4, 2, 16, 10
        q = jnp.asarray(rng.standard_normal((b, t, h, d)), jnp.float32)
        kpool = jnp.asarray(rng.standard_normal((P + 1, ps, hkv, d)),
                            jnp.float32)
        vpool = jnp.asarray(rng.standard_normal((P + 1, ps, hkv, d)),
                            jnp.float32)
        pt = jnp.asarray(rng.integers(0, P, size=(b, nb)), jnp.int32)
        # lengths INCLUDE the t-token chunk; 0 = dead slot
        lens = jnp.asarray([24, 10, 0, 4], jnp.int32)
        for window in (-1, 6):
            got = kops.paged_verify_attention(q, kpool, vpool, pt, lens,
                                              scale=0.25, window=window,
                                              interpret=True)
            want = self._oracle(q, kpool, vpool, pt, lens, window)
            live = np.asarray(lens) > 0
            err = np.abs(np.asarray(got)[live]
                         - np.asarray(want)[live]).max()
            assert err < 1e-5, (window, err)
            assert np.abs(np.asarray(got)[~live]).max() == 0.0

    def test_single_lane_matches_decode_kernel(self):
        """T=1 verify degenerates to the single-token paged kernel."""
        from repro.kernels import ops as kops
        rng = np.random.default_rng(4)
        b, ps, nb, h, hkv, d, P = 3, 8, 2, 4, 2, 16, 6
        q = jnp.asarray(rng.standard_normal((b, 1, h, d)), jnp.float32)
        kpool = jnp.asarray(rng.standard_normal((P + 1, ps, hkv, d)),
                            jnp.float32)
        vpool = jnp.asarray(rng.standard_normal((P + 1, ps, hkv, d)),
                            jnp.float32)
        pt = jnp.asarray(rng.integers(0, P, size=(b, nb)), jnp.int32)
        lens = jnp.asarray([16, 5, 9], jnp.int32)
        got = kops.paged_verify_attention(q, kpool, vpool, pt, lens,
                                          scale=0.25, interpret=True)
        want = kops.paged_decode_attention(q[:, 0], kpool, vpool, pt, lens,
                                           scale=0.25, interpret=True)
        assert np.abs(np.asarray(got[:, 0]) - np.asarray(want)).max() < 1e-6

    def test_int8_kv_mode_matches_dequant_oracle(self):
        from repro.kernels import ops as kops
        from repro.quant import int8 as int8_lib
        rng = np.random.default_rng(5)
        b, t, ps, nb, h, hkv, d, P = 3, 2, 8, 2, 4, 2, 16, 6
        q = jnp.asarray(rng.standard_normal((b, t, h, d)), jnp.float32)
        kpool = jnp.asarray(rng.standard_normal((P + 1, ps, hkv, d)),
                            jnp.float32)
        vpool = jnp.asarray(rng.standard_normal((P + 1, ps, hkv, d)),
                            jnp.float32)
        kq, ks = int8_lib.quantize_rowwise(kpool)
        vq, vs = int8_lib.quantize_rowwise(vpool)
        pt = jnp.asarray(rng.integers(0, P, size=(b, nb)), jnp.int32)
        lens = jnp.asarray([16, 5, 9], jnp.int32)
        got = kops.paged_verify_attention(q, kq, vq, pt, lens, scale=0.25,
                                          interpret=True, k_scale=ks,
                                          v_scale=vs)
        kd = int8_lib.dequantize_rowwise(kq, ks, dtype=jnp.float32)
        vd = int8_lib.dequantize_rowwise(vq, vs, dtype=jnp.float32)
        want = self._oracle(q, kd, vd, pt, lens, -1)
        assert np.abs(np.asarray(got) - np.asarray(want)).max() < 1e-5


# -----------------------------------------------------------------------------
# Device policies: n-gram drafter + rejection sampling (unit level)
# -----------------------------------------------------------------------------

class TestSpecPolicies:
    def test_ngram_draft_continues_most_recent_match(self):
        # history ... 5 6 9 | 5 6  (pending 6 at pos 4): bigram (5,6) last
        # occurred at 0 -> draft continues 9, then clamps at the pending
        hist = jnp.asarray([[5, 6, 9, 5, 6, 0, 0]], jnp.int32)
        pos = jnp.asarray([4], jnp.int32)
        d = ngram_draft(hist, pos, 3)
        assert d.tolist() == [[9, 5, 6]]

    def test_ngram_draft_no_match_repeats_pending(self):
        hist = jnp.asarray([[1, 2, 3, 4, 0, 0]], jnp.int32)
        pos = jnp.asarray([3], jnp.int32)
        d = ngram_draft(hist, pos, 2)
        assert d.tolist() == [[4, 4]]

    def _logits(self, picks, vocab=8):
        """One-hot-ish logits making ``picks`` the greedy tokens."""
        k1 = len(picks)
        lg = np.zeros((1, k1, vocab), np.float32)
        for j, p in enumerate(picks):
            lg[0, j, p] = 5.0
        return jnp.asarray(lg)

    def test_accept_all_emits_bonus(self):
        lg = self._logits([3, 1, 4, 7])
        drafts = jnp.asarray([[3, 1, 4]], jnp.int32)
        keys = jax.random.split(jax.random.PRNGKey(0), 1)
        n_acc, fix, _ = speculative_accept(lg, drafts, keys,
                                           jnp.zeros(1))
        assert int(n_acc[0]) == 3 and int(fix[0]) == 7

    def test_reject_all_emits_correction(self):
        lg = self._logits([3, 1, 4])
        drafts = jnp.asarray([[0, 0]], jnp.int32)      # never the argmax
        keys = jax.random.split(jax.random.PRNGKey(0), 1)
        n_acc, fix, _ = speculative_accept(lg, drafts, keys,
                                           jnp.zeros(1))
        assert int(n_acc[0]) == 0 and int(fix[0]) == 3

    def test_mid_rejection_emits_argmax_at_break(self):
        lg = self._logits([3, 1, 4, 6])
        drafts = jnp.asarray([[3, 2, 4]], jnp.int32)   # rejects at j=1
        keys = jax.random.split(jax.random.PRNGKey(0), 1)
        n_acc, fix, _ = speculative_accept(lg, drafts, keys,
                                           jnp.zeros(1))
        assert int(n_acc[0]) == 1 and int(fix[0]) == 1

    def test_temperature_never_emits_the_rejected_draft(self):
        """Point-mass rejection sampling: the correction token is drawn
        from the residual (the draft removed), so a rejected draft can
        never be re-emitted at its own position."""
        lg = jnp.asarray(np.random.default_rng(0).standard_normal(
            (16, 2, 8)), jnp.float32)
        drafts = jnp.full((16, 1), 2, jnp.int32)
        keys = jax.random.split(jax.random.PRNGKey(1), 16)
        temp = jnp.full(16, 1.5)
        n_acc, fix, _ = speculative_accept(lg, drafts, keys, temp)
        rejected = np.asarray(n_acc) == 0
        assert rejected.any()                   # the draw isn't degenerate
        assert not np.any(np.asarray(fix)[rejected] == 2)


# -----------------------------------------------------------------------------
# Engine: temp=0 stream identity vs the dense greedy oracle
# -----------------------------------------------------------------------------

class TestSpecIdentity:
    @pytest.mark.parametrize("k", [1, 2, 4])
    def test_fp32_ngram_token_identical(self, k):
        cfg = _cfg()
        params = _params(cfg)
        got = run_workload(_spec(params, cfg, k), RAGGED, max_tokens=8)
        want = run_workload(_dense(params, cfg), RAGGED, max_tokens=8)
        assert generation_agreement(got, want)["identical"] == 1.0

    @pytest.mark.parametrize("k", [1, 2, 4])
    def test_oracle_drafter_accepts_and_stays_identical(self, k):
        """The accept-all harness: the target model drafts itself, so at
        temp=0 every draft verifies — the speculative stream is the plain
        stream AND the per-slot-tick emission approaches k + 1."""
        cfg = _cfg()
        params = _params(cfg)
        eng = _spec(params, cfg, k, spec_drafter="oracle")
        got = run_workload(eng, RAGGED, max_tokens=2 * (k + 1) + 1)
        want = run_workload(_dense(params, cfg), RAGGED,
                            max_tokens=2 * (k + 1) + 1)
        assert generation_agreement(got, want)["identical"] == 1.0
        assert eng.summary()["accepted_tokens_per_tick"] > 1.0

    @pytest.mark.parametrize("k", [1, 2, 4])
    def test_int8_token_identical_to_int8_dense(self, k):
        cfg = _cfg()
        params = _params(cfg)
        got = run_workload(_spec(params, cfg, k, quant="int8"), RAGGED,
                           max_tokens=6)
        want = run_workload(_dense(params, cfg, quant="int8"), RAGGED,
                            max_tokens=6)
        assert generation_agreement(got, want)["identical"] == 1.0

    @pytest.mark.parametrize("k", [1, 2, 4])
    def test_chunked_prefill_token_identical(self, k):
        cfg = _cfg()
        params = _params(cfg)
        got = run_workload(_spec(params, cfg, k, prefill_chunk=8), RAGGED,
                           max_tokens=6)
        want = run_workload(_dense(params, cfg), RAGGED, max_tokens=6)
        assert generation_agreement(got, want)["identical"] == 1.0

    def test_decode_kernel_token_identical(self):
        """End-to-end through the multi-query verify Pallas kernel
        (interpret mode on CPU)."""
        cfg = _cfg()
        params = _params(cfg)
        prompts = [np.arange(4), np.arange(3) + 7]
        got = run_workload(
            ServeEngine(params, cfg,
                        ServeConfig(max_slots=2, max_len=16, paged=True,
                                    page_size=4, decode_kernel=True,
                                    spec_k=2)), prompts, max_tokens=3)
        want = run_workload(
            ServeEngine(params, cfg, ServeConfig(max_slots=2, max_len=16)),
            prompts, max_tokens=3)
        assert generation_agreement(got, want)["identical"] == 1.0

    def test_reject_every_draft_still_exact(self, monkeypatch):
        """A drafter whose proposals are never the argmax (it drafts a
        vocab-pad token the true-vocab argmax can't equal): every tick is
        a pure rewind — k stale writes masked out behind the unadvanced
        length — and the stream must still be the plain greedy stream at
        one token per slot-tick."""
        cfg = _cfg(vocab=61, pad=64)             # embed rows 61..63 exist
        params = _params(cfg)

        def never_matches(hist, pos, k):
            return jnp.full((hist.shape[0], k), 63, jnp.int32)

        monkeypatch.setattr(spec_lib, "ngram_draft", never_matches)
        eng = _spec(params, cfg, 3)
        got = run_workload(eng, RAGGED, max_tokens=6)
        want = run_workload(_dense(params, cfg), RAGGED, max_tokens=6)
        assert generation_agreement(got, want)["identical"] == 1.0
        s = eng.summary()
        assert s["accept_rate"] == 0.0
        assert s["accepted_tokens_per_tick"] == 1.0

    @pytest.mark.parametrize("mt", [1, 2, 3])
    def test_finish_inside_accepted_run(self, mt):
        """max_tokens below k: the budget exhausts mid-draft-run and the
        emission clamp must stop exactly where the plain engine stops."""
        cfg = _cfg()
        params = _params(cfg)
        got = run_workload(_spec(params, cfg, 4, spec_drafter="oracle"),
                           RAGGED, max_tokens=mt)
        want = run_workload(_dense(params, cfg), RAGGED, max_tokens=mt)
        assert generation_agreement(got, want)["identical"] == 1.0

    def test_eos_inside_accepted_run(self):
        """An EOS accepted mid-run truncates the emission there — same
        stream as the plain engine with the same eos_id."""
        cfg = _cfg()
        params = _params(cfg)
        ref = run_workload(_dense(params, cfg), RAGGED, max_tokens=10)
        eos = next(g[2] for g in ref.values() if len(g) > 3)
        got = run_workload(_spec(params, cfg, 4, eos_id=eos), RAGGED,
                           max_tokens=10)
        want = run_workload(_dense(params, cfg, eos_id=eos), RAGGED,
                            max_tokens=10)
        assert generation_agreement(got, want)["identical"] == 1.0

    def test_max_len_cap_inside_accepted_run(self):
        """Generation running into the context cap: draft lanes past
        max_len sink-write and the emission clamp stops at max_len - 1."""
        cfg = _cfg()
        params = _params(cfg)
        prompts = [np.arange(10), np.arange(7) + 3]
        got = run_workload(
            ServeEngine(params, cfg,
                        ServeConfig(max_slots=2, max_len=16, paged=True,
                                    page_size=4, spec_k=4)),
            prompts, max_tokens=12)
        want = run_workload(
            ServeEngine(params, cfg, ServeConfig(max_slots=2, max_len=16)),
            prompts, max_tokens=12)
        assert generation_agreement(got, want)["identical"] == 1.0

    def test_sampling_deterministic_given_seed(self):
        cfg = _cfg()
        params = _params(cfg)

        def run():
            eng = _spec(params, cfg, 2, seed=0)
            for p in RAGGED:
                eng.submit(p, max_tokens=5, temperature=0.7)
            return {r.uid: tuple(r.generated)
                    for r in eng.run_until_drained()}

        assert run() == run()

    def test_tick_stays_fused(self):
        """One trace, one readback per tick — speculation must not cost
        the device-residency discipline."""
        cfg = _cfg()
        params = _params(cfg)
        eng = _spec(params, cfg, 2)
        eng.submit(np.arange(6), max_tokens=30)
        eng.step()
        base = eng.host_readbacks
        ticks = eng.tick_trace_count
        for i in range(3):
            eng.step()
            assert eng.host_readbacks == base + (i + 1)
        assert eng.tick_trace_count == ticks == 1

    def test_spec_requires_paged(self):
        cfg = _cfg()
        with pytest.raises(ValueError, match="paged"):
            ServeEngine({}, cfg, ServeConfig(max_slots=1, spec_k=2))
        with pytest.raises(ValueError, match="drafter"):
            ServeEngine({}, cfg, ServeConfig(max_slots=1, paged=True,
                                             spec_k=2,
                                             spec_drafter="psychic"))


# -----------------------------------------------------------------------------
# Accounting: draft vs verify billed separately (satellite)
# -----------------------------------------------------------------------------

class TestSpecAccounting:
    def test_verify_tick_bill_hand_computed(self):
        """First speculative tick after a chunk-free admission: the
        verify pass streams weights once and bills k+1 lanes of causal
        attention; the n-gram drafter bills one history scan."""
        cfg = _cfg()
        params = _params(cfg)
        k = 2
        eng = ServeEngine(params, cfg,
                          ServeConfig(max_slots=1, max_len=64, paged=True,
                                      page_size=4, spec_k=k))
        eng.submit(np.arange(8), max_tokens=12)
        eng.step()                  # admission + the slot's first spec tick
        eng.step()                  # a pure spec tick
        m = eng.metrics_log[-1]
        width = k + 1
        # live context: prompt + admission token + tick-0's spec emission
        ctx = 8 + 1 + eng.metrics_log[0].tokens
        elems, n_attn = eng._matmul_elems, eng._n_attn
        dims = eng._attn_dims
        want_v = (2.0 * elems * width
                  + 4.0 * n_attn * dims
                  * (width * ctx + width * (width - 1) / 2.0))
        assert m.verify_flops == pytest.approx(want_v)
        assert m.draft_flops == 0.0                 # ngram drafts for free
        assert m.draft_bytes == 4.0 * 64            # one int32 history row
        assert m.verify_bytes == pytest.approx(
            eng.weight_bytes + eng._kv_token_bytes * (ctx + 2.0 * width))
        assert m.flops == pytest.approx(want_v)     # no admission this tick
        assert m.spec_draft_tokens == k
        assert m.spec_accepted_tokens == m.tokens - 1

    def test_accountant_spec_report(self):
        cfg = _cfg()
        params = _params(cfg)
        acct = accounting.CarbonAccountant(accounting.AccountantConfig(
            device="tpu_v5e", n_devices=1, grid_mix="NY"))
        eng = _spec(params, cfg, 2)
        eng.accountant = acct
        run_workload(eng, RAGGED, max_tokens=6)
        rep = acct.report()
        assert "spec" in rep
        spec = rep["spec"]
        assert spec["draft_tokens"] > 0
        assert 0.0 <= spec["accept_rate"] <= 1.0
        assert spec["verify_j"] > 0
        assert spec["j_per_accepted_token"] > 0
        # totals stay consistent: the spec split is part of bytes_moved
        assert rep["bytes_moved"] >= spec["verify_bytes"]

    def test_oracle_drafter_bills_extra_weight_streams(self):
        cfg = _cfg()
        params = _params(cfg)
        k = 3
        eng = ServeEngine(params, cfg,
                          ServeConfig(max_slots=1, max_len=64, paged=True,
                                      page_size=4, spec_k=k,
                                      spec_drafter="oracle"))
        eng.submit(np.arange(8), max_tokens=12)
        eng.step()
        eng.step()
        m = eng.metrics_log[-1]
        assert m.draft_flops > 0
        assert m.draft_bytes > k * 0.9 * eng.weight_bytes
        assert m.weight_bytes == pytest.approx((k + 1) * eng.weight_bytes)


# -----------------------------------------------------------------------------
# Stats correctness sweep (satellites 1-3)
# -----------------------------------------------------------------------------

class TestStatsRegressions:
    def test_pool_stats_zero_lookups_hit_rate(self):
        assert PoolStats().hit_rate == 0.0
        pool = PagePool(4, page_size=4)
        assert pool.stats.hit_rate == 0.0
        repr(pool)                                  # formats without raising

    def test_summary_zero_ticks_and_zero_tokens(self):
        """A paged+spec engine that never served must summarize to clean
        zeros — no NaN, no ZeroDivisionError (satellite regression)."""
        cfg = _cfg()
        params = _params(cfg)
        eng = _spec(params, cfg, 2)
        s = eng.summary()
        assert s["decode_tokens_per_s"] == 0.0
        assert s["prefix_hit_rate"] == 0.0
        assert s["pool_hit_rate"] == 0.0
        assert s["accept_rate"] == 0.0
        assert s["accepted_tokens_per_tick"] == 0.0
        assert all(v == v for v in s.values() if isinstance(v, float))
        # accountant mirror: a report with zero tokens is None-guarded
        acct = accounting.CarbonAccountant(accounting.AccountantConfig(
            device="tpu_v5e", n_devices=1, grid_mix="NY"))
        rep = acct.report()
        assert rep["prefix_hit_rate"] == 0.0 and "spec" not in rep

    def test_unbook_lookup_restores_counts(self):
        pool = PagePool(8, page_size=2)
        from repro.serve import block_tokens
        blocks = block_tokens(np.arange(6), 2)
        pages = pool.alloc(3)
        parent = -1
        for p, blk in zip(pages, blocks):
            parent = pool.publish(p, parent, blk)
        pool.release_all(pages)
        hits = pool.lookup(blocks)
        assert (pool.stats.hit_blocks, pool.stats.missed_blocks) == (3, 0)
        pool.release_all(hits)
        pool.unbook_lookup(3, 3)
        assert (pool.stats.hit_blocks, pool.stats.missed_blocks) == (0, 0)
        assert pool.stats.hit_rate == 0.0

    def test_deferred_admission_books_stats_once(self):
        """Hand-computed PoolStats through a defer-retry cycle: request B
        (same prompt as A) waits behind A on a pool with capacity for one,
        deferred by the fits gate for many ticks. Deferral must book NO
        lookup stats; the final ledger is exactly one booking per
        admission: A missed its 2 blocks, B hit them."""
        cfg = _cfg()
        params = _params(cfg)
        eng = ServeEngine(params, cfg,
                          ServeConfig(max_slots=2, max_len=64, paged=True,
                                      page_size=4, num_pages=4))
        P = np.arange(8)
        eng.submit(P, max_tokens=8)                 # A: needs all 4 pages
        eng.submit(P, max_tokens=8)                 # B: deferred until A ends
        done = eng.run_until_drained()
        assert len(done) == 2
        st = eng.pool.stats
        assert st.missed_blocks == 2                # A's two blocks, once
        assert st.hit_blocks == 2                   # B's two hits, once
        assert st.alloc_failures == 0               # fits-gated, no race
        assert st.hit_rate == pytest.approx(0.5)

    def test_defer_admission_helper_rolls_back(self):
        """The centralized deferral path: stats and refcounts return to
        their pre-lookup values and the request requeues head-of-line."""
        cfg = _cfg()
        params = _params(cfg)
        eng = ServeEngine(params, cfg,
                          ServeConfig(max_slots=2, max_len=64, paged=True,
                                      page_size=4))
        P = np.arange(8)
        run_workload(eng, [P], max_tokens=2)        # publish P's blocks
        from repro.serve import block_tokens
        before = dataclasses.replace(eng.pool.stats)
        blocks = block_tokens(P, 4)
        hits = eng.pool.lookup(blocks)
        assert len(hits) == 2
        req = Request(99, P, 4)
        eng._defer_admission(req, hits, len(hits), len(blocks), [])
        assert eng.pool.stats == before
        assert all(eng.pool.refcount(p) == 0 for p in hits)
        assert eng.scheduler.pending[0] is req

    def test_oversized_queued_request_dropped_not_livelocked(self):
        """A never-fittable request that reached the queue directly (past
        the submit guard) is dropped and failed fast — with no lookup
        stats booked — instead of pinning FIFO admission forever."""
        cfg = _cfg()
        params = _params(cfg)
        eng = ServeEngine(params, cfg,
                          ServeConfig(max_slots=2, max_len=64, paged=True,
                                      page_size=4, num_pages=4))
        big = Request(7777, np.arange(30), 16)      # needs 12 > 4 pages
        eng.scheduler.submit(big)
        eng.submit(np.arange(8), max_tokens=2)      # must still be served
        done = eng.run_until_drained()
        assert {r.uid for r in done} == {7777, 1}
        assert big.done and big.generated == []
        assert eng.pool.stats.missed_blocks == 2    # only the real request
        assert eng.pool.stats.hit_blocks == 0

    def test_finish_publishes_full_blocks_before_release(self):
        """Satellite: a finished stream's exactly-full final block becomes
        a reusable prefix. Publishing happens BEFORE release_all (a page
        released unpublished would go to the free list and be recyclable),
        and pool refcounts return to baseline after drain."""
        cfg = _cfg()
        params = _params(cfg)
        eng = ServeEngine(params, cfg,
                          ServeConfig(max_slots=2, max_len=64, paged=True,
                                      page_size=4))
        P = np.arange(6)
        gen = list(run_workload(eng, [P], max_tokens=6).values())[0]
        # cached stream = prompt + generated[:-1] = 11 tokens -> blocks
        # 0 (prompt) and 1 (prompt tail + first generated) are published
        assert len(eng.pool.cached_pages()) == 2
        assert eng.pool.live == 0
        assert all(eng.pool.refcount(p) == 0
                   for p in range(eng.pool.num_pages))
        # a prompt continuing into the generation hits the decode-grown
        # block: 8 of its tokens (2 blocks) come from the registry
        probe = np.concatenate([P, gen[:4]])
        eng.submit(probe, max_tokens=2)
        eng.step()
        assert eng.metrics_log[-1].prefix_hit_tokens == 8

    def test_partial_final_block_not_published(self):
        """Only full, frozen blocks are shareable: a stream whose cache
        ends mid-block publishes the full prefix blocks only."""
        cfg = _cfg()
        params = _params(cfg)
        eng = ServeEngine(params, cfg,
                          ServeConfig(max_slots=1, max_len=64, paged=True,
                                      page_size=4))
        run_workload(eng, [np.arange(5)], max_tokens=2)   # cache = 6 toks
        assert len(eng.pool.cached_pages()) == 1          # block 0 only

    def test_spec_mode_refcounts_baseline_after_drain(self):
        """Speculative ticks transiently write k positions past the
        committed length; after drain nothing may leak — refcounts at
        zero, live pages zero."""
        cfg = _cfg()
        params = _params(cfg)
        eng = _spec(params, cfg, 4)
        run_workload(eng, RAGGED, max_tokens=6)
        assert eng.pool.live == 0
        assert all(eng.pool.refcount(p) == 0
                   for p in range(eng.pool.num_pages))

    def test_spec_booking_counts_draft_growth(self):
        """The page budget books worst-case k-token growth per tick
        (scheduler/fits + the submit guard share _pages_needed)."""
        cfg = _cfg()
        params = _params(cfg)
        eng = _spec(params, cfg, 4)
        # 8 + 4 + spec_k(4) = 16 tokens -> 4 pages
        assert eng._pages_needed(8, 4) == 4
        plain = ServeEngine(params, cfg,
                            ServeConfig(max_slots=2, max_len=64, paged=True,
                                        page_size=4))
        assert plain._pages_needed(8, 4) == 3
