"""Sharding rule engine: divisibility fallbacks, conflicts, overrides."""

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.parallel import sharding as sh


@pytest.fixture(scope="module")
def mesh():
    # 1-device meshes can't test divisibility; fake a (2,4) logical mesh by
    # reusing the single device? No — sizes matter. Use Mesh with repeated
    # devices is illegal; instead build an abstract mesh via mesh_utils on 1
    # device -> sizes 1. So: use jax.sharding.Mesh over a reshaped device
    # array is impossible here; we instead monkeypatch _axis_size via a stub.
    class FakeMesh:
        shape = {"pod": 2, "data": 16, "model": 16}
    return FakeMesh()


class TestSpecFor:
    def test_basic_tp(self, mesh):
        spec = sh.spec_for((4096, 64, 128), ("embed", "heads", "head_dim"), mesh)
        assert spec == P(None, "model")

    def test_divisibility_fallback_heads(self, mesh):
        # 36 heads % 16 != 0 -> replicate heads, head_dim picks up model
        spec = sh.spec_for((4608, 36, 128), ("embed", "heads", "head_dim"), mesh)
        assert spec == P(None, None, "model")

    def test_mqa_kv_replicated_headdim_sharded(self, mesh):
        spec = sh.spec_for((6144, 1, 128), ("embed", "kv_heads", "head_dim"), mesh)
        assert spec == P(None, None, "model")

    def test_conflict_left_to_right(self, mesh):
        # MoE w_in (experts, embed, ffn): experts wins "model", ffn falls back
        spec = sh.spec_for((64, 2048, 1408), ("experts", "embed", "ffn"), mesh)
        assert spec == P("model")

    def test_batch_over_pod_and_data(self, mesh):
        spec = sh.spec_for((256, 4096), ("batch", "seq"), mesh)
        assert spec == P(("pod", "data"))

    def test_batch_fallback_when_indivisible(self, mesh):
        spec = sh.spec_for((1, 4096), ("batch", "seq"), mesh)
        assert spec == P()

    def test_long_context_rules_shard_seq(self, mesh):
        spec = sh.spec_for((1, 524288), ("batch", "seq"), mesh,
                           sh.LONG_CONTEXT_RULES)
        assert spec == P(None, ("pod", "data"))

    def test_missing_pod_axis_dropped(self):
        class SinglePod:
            shape = {"data": 16, "model": 16}
        spec = sh.spec_for((256, 128), ("batch", "seq"), SinglePod())
        assert spec == P("data")

    def test_vocab_pad_dependency(self, mesh):
        # padded vocab shards; unpadded 50280 does not
        assert sh.spec_for((50304, 2048), ("vocab", "embed"), mesh) == P("model")
        assert sh.spec_for((50280, 2048), ("vocab", "embed"), mesh) == P()


class TestTrees:
    def test_specs_for_params_tree(self, mesh):
        import jax.numpy as jnp
        from repro.models import transformer as tf_lib
        cfg = tf_lib.LMConfig(name="t", d_model=64, n_heads=16, n_kv_heads=16,
                              d_ff=128, vocab=128,
                              pattern=(tf_lib.BlockSpec(),), repeats=2)
        ax = jax.eval_shape(lambda k: tf_lib.init_lm(k, cfg, jnp.bfloat16),
                            jax.random.PRNGKey(0))
        specs = sh.specs_for_tree(ax.params, ax.axes, mesh)
        assert specs["embed"]["w"] == P("model")
        attn = specs["pat0"]["attn"]
        assert attn["wq"] == P(None, None, "model")  # stack, embed, heads...
        hist = sh.summarize(specs)
        assert sum(hist.values()) == len(jax.tree.leaves(ax.params))

    def test_cache_axes_tree(self, mesh):
        import jax.numpy as jnp
        from functools import partial
        from repro.models import transformer as tf_lib
        cfg = tf_lib.LMConfig(name="t", d_model=64, n_heads=16, n_kv_heads=16,
                              d_ff=128, vocab=128,
                              pattern=(tf_lib.BlockSpec(),), repeats=2)
        caches = jax.eval_shape(partial(tf_lib.init_caches, cfg, 32, 64,
                                        jnp.bfloat16))
        specs = sh.specs_for_tree(caches, tf_lib.caches_axes(cfg), mesh)
        assert specs["pat0"]["kv"].k == P(None, ("pod", "data"), None, "model")
