"""Mamba2 SSD: chunked == naive recurrence; block decode == full sequence."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.models import ssd as ssd_lib


def _inputs(key, b, s, h, p, n):
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    x = jax.random.normal(k1, (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(k2, (b, s, h)))
    a = -jnp.exp(jax.random.normal(k3, (h,)))
    bm = jax.random.normal(k4, (b, s, h, n))
    cm = jax.random.normal(k5, (b, s, h, n))
    return x, dt, a, bm, cm


@pytest.mark.parametrize("s,chunk", [(32, 8), (64, 16), (40, 16), (8, 8)])
def test_chunked_equals_naive(s, chunk):
    x, dt, a, bm, cm = _inputs(jax.random.PRNGKey(0), 2, s, 4, 8, 16)
    y_ref, st_ref = ssd_lib.ssd_naive(x, dt, a, bm, cm)
    y, st_ = ssd_lib.ssd_chunked(x, dt, a, bm, cm, chunk)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=2e-4)
    np.testing.assert_allclose(np.asarray(st_), np.asarray(st_ref), atol=2e-4)


def test_initial_state_threading():
    x, dt, a, bm, cm = _inputs(jax.random.PRNGKey(1), 1, 32, 2, 4, 8)
    # run in two halves with state carry == full run
    y_full, st_full = ssd_lib.ssd_chunked(x, dt, a, bm, cm, 8)
    y1, st1 = ssd_lib.ssd_chunked(x[:, :16], dt[:, :16], a, bm[:, :16],
                                  cm[:, :16], 8)
    y2, st2 = ssd_lib.ssd_chunked(x[:, 16:], dt[:, 16:], a, bm[:, 16:],
                                  cm[:, 16:], 8, init_state=st1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), atol=2e-4)
    np.testing.assert_allclose(np.asarray(st2), np.asarray(st_full), atol=2e-4)


@given(st.integers(1, 3), st.integers(2, 6))
@settings(max_examples=8, deadline=None)
def test_state_decay_bounded(b, h):
    """With x = 0 the state must decay monotonically (|A| < 0)."""
    s, p, n = 16, 4, 8
    x = jnp.zeros((b, s, h, p))
    dt = jnp.ones((b, s, h)) * 0.5
    a = -jnp.ones((h,))
    bm = jnp.zeros((b, s, h, n))
    cm = jnp.zeros((b, s, h, n))
    init = jnp.ones((b, h, n, p))
    _, st_out = ssd_lib.ssd_chunked(x, dt, a, bm, cm, 8, init_state=init)
    assert float(jnp.max(jnp.abs(st_out))) < 1.0


def test_block_decode_equals_full():
    cfg = ssd_lib.SSDConfig(d_model=32, d_state=16, head_dim=8, expand=2,
                            chunk=8)
    ax = ssd_lib.init_ssd(jax.random.PRNGKey(2), cfg, dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 12, 32)) * 0.5
    y_full = ssd_lib.ssd_block(ax.params, cfg, x)
    state = ssd_lib.init_ssd_state(cfg, 2, dtype=jnp.float32)
    ys = []
    for t in range(12):
        yt, state = ssd_lib.ssd_block_decode(ax.params, cfg, x[:, t:t + 1],
                                             state)
        ys.append(yt)
    np.testing.assert_allclose(np.asarray(y_full),
                               np.asarray(jnp.concatenate(ys, 1)), atol=1e-4)


def test_block_grads_finite():
    cfg = ssd_lib.SSDConfig(d_model=32, d_state=8, head_dim=8, expand=2,
                            chunk=8)
    ax = ssd_lib.init_ssd(jax.random.PRNGKey(4), cfg, dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(5), (2, 16, 32))

    def loss(p):
        return jnp.sum(ssd_lib.ssd_block(p, cfg, x) ** 2)

    g = jax.grad(loss)(ax.params)
    assert all(bool(jnp.isfinite(v).all()) for v in jax.tree.leaves(g))
