"""Eq. 1 properties + every Figure-2 claim of the paper, reproduced."""

import math

import pytest
from hypothesis import given, settings, strategies as st

from repro.core import sustain
from repro.core.sustain import Duty, SECONDS_PER_DAY, SECONDS_PER_YEAR


class TestEq1Properties:
    def test_tb_equals_ti_when_m0_zero(self):
        """Paper: t_B = t_I when M_0 = 0."""
        assert sustain.breakeven_time_s(5e6, 3.0, 1.0) == pytest.approx(
            sustain.indifference_time_s(5e6, 0.0, 3.0, 1.0))

    def test_never_amortizes(self):
        assert math.isinf(sustain.indifference_time_s(5e6, 1e6, 1.0, 2.0))

    def test_dominant_choice_needs_no_indifference(self):
        """Lower embodied AND lower operational -> t_I = 0 (pick it always)."""
        assert sustain.indifference_time_s(1e6, 5e6, 1.0, 2.0) == 0.0

    @given(st.floats(1e5, 1e8), st.floats(0, 1e7), st.floats(0.1, 50),
           st.floats(0.01, 45))
    @settings(max_examples=50, deadline=None)
    def test_ti_consistency(self, m1, m0, p0, p1_frac):
        p1 = p1_frac
        t = sustain.indifference_time_s(m1 + m0, m0, p0 + p1, p1)
        # at t, holistic energies are equal (when finite and positive)
        if 0 < t < float("inf"):
            e1 = sustain.total_energy_j(m1 + m0, p1, t)
            e0 = sustain.total_energy_j(m0, p0 + p1, t)
            assert e1 == pytest.approx(e0, rel=1e-6)

    @given(st.floats(0.05, 1.0), st.floats(0.0, 1.0))
    @settings(max_examples=40, deadline=None)
    def test_avg_power_within_bounds(self, act, sleep):
        from repro.core import hw
        p = hw.PowerStates(10.0, 2.0, 0.5)
        avg = sustain.average_power_w(p, act, sleep)
        assert p.sleep_w <= avg <= p.active_w


def _inference_platforms():
    rm = sustain.platform_from_hw("rm_pim", "alexnet", "inference_ternary",
                                  per_module=True)
    ddr = sustain.platform_from_hw("ddr3_pim", "alexnet", "inference_ternary",
                                   per_module=True)
    return rm, ddr


class TestPaperClaimsBreakeven:
    """Fig 2a / conclusion: RM PIM replacing deployed DDR3 PIM."""

    def test_breakeven_full_activity_about_one_year(self):
        rm, ddr = _inference_platforms()
        c = sustain.compare(rm, ddr, Duty(1.0), ref_throughput=ddr.throughput)
        days = c.breakeven_s / SECONDS_PER_DAY
        # paper: "can recover its embodied energy as quickly as 1 year"
        assert 270 <= days <= 400, days

    def test_breakeven_half_activity_about_500_days(self):
        rm, ddr = _inference_platforms()
        c = sustain.compare(rm, ddr, Duty(0.5), ref_throughput=ddr.throughput)
        days = c.breakeven_s / SECONDS_PER_DAY
        assert 430 <= days <= 570, days   # paper: "around 500 days"

    def test_low_usage_two_to_three_years(self):
        rm, ddr = _inference_platforms()
        c = sustain.compare(rm, ddr, Duty(0.22), ref_throughput=ddr.throughput)
        years = c.breakeven_s / SECONDS_PER_YEAR
        assert 1.8 <= years <= 3.2, years

    def test_breakeven_monotone_in_activity(self):
        rm, ddr = _inference_platforms()
        prev = math.inf
        for a in (0.1, 0.3, 0.5, 0.8, 1.0):
            c = sustain.compare(rm, ddr, Duty(a), ref_throughput=ddr.throughput)
            assert c.breakeven_s <= prev
            prev = c.breakeven_s

    def test_surface_shape(self):
        rm, ddr = _inference_platforms()
        surf = sustain.surface(rm, ddr, [0.25, 0.5, 1.0], [0.0, 0.5, 1.0],
                               "breakeven", ref_throughput=ddr.throughput)
        assert surf.shape == (3, 3)
        assert (surf > 0).all()


class TestPaperClaimsIndifference:
    """Fig 2b/2c + conclusion: GPU vs RM for FP32 training."""

    def test_alexnet_crossover_at_40pct(self):
        gpu = sustain.platform_from_hw("gpu", "alexnet", "train_fp32")
        rm = sustain.platform_from_hw("rm_pim", "alexnet", "train_fp32")
        a = sustain.crossover_activity(gpu, rm, ref_throughput=rm.throughput)
        # paper: "activity ratio needs to be at least 40% for ... Alexnet"
        assert 0.37 <= a <= 0.44, a

    def test_alexnet_impractical_below_crossover_plus_eps(self):
        gpu = sustain.platform_from_hw("gpu", "alexnet", "train_fp32")
        rm = sustain.platform_from_hw("rm_pim", "alexnet", "train_fp32")
        c = sustain.compare(gpu, rm, Duty(0.41), ref_throughput=rm.throughput)
        # paper: impractical (>10 yr) in the low/mid-40% range
        assert c.indifference_s / SECONDS_PER_YEAR > 10.0

    def test_alexnet_practical_at_high_activity(self):
        gpu = sustain.platform_from_hw("gpu", "alexnet", "train_fp32")
        rm = sustain.platform_from_hw("rm_pim", "alexnet", "train_fp32")
        c = sustain.compare(gpu, rm, Duty(1.0), ref_throughput=rm.throughput)
        assert c.indifference_s / SECONDS_PER_YEAR < 0.5

    def test_vgg_crossover_higher_than_alexnet(self):
        """Paper: 'VGG-16 ... falls off sooner' (higher required activity)."""
        gpu_a = sustain.platform_from_hw("gpu", "alexnet", "train_fp32")
        rm_a = sustain.platform_from_hw("rm_pim", "alexnet", "train_fp32")
        gpu_v = sustain.platform_from_hw("gpu", "vgg16", "train_fp32")
        rm_v = sustain.platform_from_hw("rm_pim", "vgg16", "train_fp32")
        a_alex = sustain.crossover_activity(gpu_a, rm_a,
                                            ref_throughput=rm_a.throughput)
        a_vgg = sustain.crossover_activity(gpu_v, rm_v,
                                           ref_throughput=rm_v.throughput)
        assert a_vgg > a_alex
        assert 0.45 <= a_vgg <= 0.56, a_vgg

    def test_fpga_never_selected(self):
        """Paper: 'the indifference calculation will never pick the FPGA'."""
        from repro.core import advisor
        gpu = sustain.platform_from_hw("gpu", "alexnet", "train_fp32")
        rm = sustain.platform_from_hw("rm_pim", "alexnet", "train_fp32")
        fpga = sustain.platform_from_hw("fpga", "alexnet", "train_fp32")
        rec = advisor.recommend([gpu, rm, fpga], Duty(0.7),
                                5 * SECONDS_PER_YEAR,
                                ref_throughput=rm.throughput)
        assert "fpga" in rec.dominated
        assert rec.winner != "fpga"

    def test_decision_flips_with_service_time(self):
        gpu = sustain.platform_from_hw("gpu", "alexnet", "train_fp32")
        rm = sustain.platform_from_hw("rm_pim", "alexnet", "train_fp32")
        duty = Duty(0.5)
        short = sustain.decide([gpu, rm], duty, 0.2 * SECONDS_PER_YEAR,
                               ref_throughput=rm.throughput)
        long = sustain.decide([gpu, rm], duty, 10 * SECONDS_PER_YEAR,
                              ref_throughput=rm.throughput)
        assert min(short, key=short.get) == "rm_pim"   # embodied dominates
        assert min(long, key=long.get) == "gpu"        # operational dominates
