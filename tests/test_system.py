"""End-to-end system behaviour: the paper's full loop on a small model.

Train a reduced-config arch with the CarbonAccountant in the loop, checkpoint,
restore, serve from the trained params, and run the sustainability advisor on
the measured operational profile — the paper's holistic evaluation, live.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointConfig
from repro.core import accounting, sustain
from repro.core.sustain import Duty
from repro.data import DataConfig, make_pipeline
from repro.launch.train import build_smoke_trainer
from repro.models import transformer as tf_lib
from repro.optim import AdamWConfig
from repro.serve import ServeConfig, ServeEngine
from repro.train import TrainConfig, Trainer


def test_end_to_end_train_checkpoint_serve_account(tmp_path):
    cfg = tf_lib.LMConfig(name="e2e", d_model=48, n_heads=4, n_kv_heads=4,
                          d_ff=96, vocab=64, pattern=(tf_lib.BlockSpec(),),
                          repeats=2, remat="none", vocab_pad_multiple=1)
    params = tf_lib.init_lm(jax.random.PRNGKey(0), cfg, dtype=jnp.float32).params
    pipe = make_pipeline(DataConfig(vocab=64, seq_len=32, global_batch=8,
                                    source="markov"))
    acct = accounting.CarbonAccountant(accounting.AccountantConfig(
        device="tpu_v5e", n_devices=1, grid_mix="CA"))
    tr = Trainer(loss_fn=lambda p, b: tf_lib.loss_fn(p, cfg, b),
                 params=params, opt_cfg=AdamWConfig(lr=3e-3),
                 train_cfg=TrainConfig(num_steps=40, log_every=10,
                                       checkpoint_every=20),
                 pipeline=pipe, accountant=acct,
                 ckpt_cfg=CheckpointConfig(str(tmp_path)))
    metrics = tr.run()
    assert metrics["loss"] < 4.0

    # accounting observed every step
    rep = acct.report()
    assert rep["steps"] == 40
    assert rep["tokens"] == 40 * 8 * 32
    assert rep["operational_gco2"] > 0
    assert 0 < rep["amortized_fraction"] < 1

    # restore into a fresh trainer (restart path)
    tr2 = Trainer(loss_fn=lambda p, b: tf_lib.loss_fn(p, cfg, b),
                  params=tf_lib.init_lm(jax.random.PRNGKey(5), cfg,
                                        dtype=jnp.float32).params,
                  opt_cfg=AdamWConfig(lr=3e-3), train_cfg=TrainConfig(),
                  pipeline=make_pipeline(DataConfig(vocab=64, seq_len=32,
                                                    global_batch=8,
                                                    source="markov")),
                  ckpt_cfg=CheckpointConfig(str(tmp_path)))
    assert tr2.maybe_restore()
    assert tr2.step_num == 40

    # serve from the trained params
    eng = ServeEngine(tr.params, cfg, ServeConfig(max_slots=2, max_len=48,
                                                  cache_dtype=jnp.float32))
    eng.submit(np.arange(6), max_tokens=4)
    done = eng.run_until_drained()
    assert len(done) == 1 and len(done[0].generated) == 4


def test_smoke_trainer_builder_all_families():
    """launch.train builds a runnable smoke trainer for every family."""
    for arch_id in ("mamba2-1.3b", "moonshot-v1-16b-a3b", "whisper-large-v3"):
        tr = build_smoke_trainer(arch_id, steps=2, ckpt_dir=None,
                                 global_batch=4, seq_len=16)
        m = tr.run()
        assert np.isfinite(m["loss"])


def test_advisor_closes_the_loop():
    """The paper's question, asked of measured numbers: given this duty cycle
    and service time, which platform minimizes holistic energy?"""
    from repro.core import advisor
    gpu = sustain.platform_from_hw("gpu", "alexnet", "train_fp32")
    rm = sustain.platform_from_hw("rm_pim", "alexnet", "train_fp32")
    rec = advisor.recommend([gpu, rm], Duty(0.9),
                            3 * sustain.SECONDS_PER_YEAR,
                            ref_throughput=rm.throughput)
    assert rec.winner == "gpu"     # high duty, multi-year: GPU amortizes
    rec2 = advisor.recommend([gpu, rm], Duty(0.2),
                             3 * sustain.SECONDS_PER_YEAR,
                             ref_throughput=rm.throughput)
    assert rec2.winner == "rm_pim"  # low duty: idle power kills the GPU
