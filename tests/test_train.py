"""Training loop: convergence, grad-accum equivalence, FT behaviours."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointConfig
from repro.data import DataConfig, make_pipeline
from repro.models import transformer as tf_lib
from repro.optim import AdamWConfig, init_opt_state
from repro.train import TrainConfig, Trainer, make_train_step


def _tiny(seed=0, vocab=64):
    cfg = tf_lib.LMConfig(name="t", d_model=48, n_heads=4, n_kv_heads=4,
                          d_ff=96, vocab=vocab, pattern=(tf_lib.BlockSpec(),),
                          repeats=2, remat="none", vocab_pad_multiple=1)
    ax = tf_lib.init_lm(jax.random.PRNGKey(seed), cfg, dtype=jnp.float32)
    return cfg, ax.params


class TestConvergence:
    def test_loss_decreases_on_markov(self):
        cfg, params = _tiny()
        pipe = make_pipeline(DataConfig(vocab=64, seq_len=32, global_batch=8,
                                        source="markov"))
        tr = Trainer(loss_fn=lambda p, b: tf_lib.loss_fn(p, cfg, b),
                     params=params, opt_cfg=AdamWConfig(lr=3e-3),
                     train_cfg=TrainConfig(num_steps=50, log_every=10),
                     pipeline=pipe)
        tr.run()
        losses = [e["loss"] for e in tr.metrics_log]
        assert losses[-1] < losses[0] - 0.3, losses


class TestGradAccum:
    def test_accum_equals_full_batch(self):
        cfg, params = _tiny(seed=1)
        key = jax.random.PRNGKey(2)
        batch = {"tokens": jax.random.randint(key, (8, 16), 0, 64),
                 "labels": jax.random.randint(key, (8, 16), 0, 64)}
        opt_cfg = AdamWConfig(lr=1e-2, grad_clip=0.0, weight_decay=0.0)
        s1 = make_train_step(lambda p, b: tf_lib.loss_fn(p, cfg, b), opt_cfg, 1)
        s4 = make_train_step(lambda p, b: tf_lib.loss_fn(p, cfg, b), opt_cfg, 4)
        st = init_opt_state(params, opt_cfg)
        p1, _, m1 = s1(params, st, batch)
        p4, _, m4 = s4(params, init_opt_state(params, opt_cfg), batch)
        assert float(m1["loss"]) == pytest.approx(float(m4["loss"]), rel=1e-5)
        for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=5e-5)


class TestFaultTolerance:
    def test_restart_resumes_exact_stream(self, tmp_path):
        """Kill after N steps, restart: same data + same step count."""
        cfg, params = _tiny(seed=3)
        mk = lambda: make_pipeline(DataConfig(vocab=64, seq_len=16,
                                              global_batch=4, source="markov"))
        common = dict(loss_fn=lambda p, b: tf_lib.loss_fn(p, cfg, b),
                      opt_cfg=AdamWConfig(lr=1e-3),
                      ckpt_cfg=CheckpointConfig(str(tmp_path)))
        tr = Trainer(params=params, pipeline=mk(),
                     train_cfg=TrainConfig(num_steps=6, checkpoint_every=3,
                                           log_every=1), **common)
        tr.run(3)
        tr.save(wait=True)
        tr2 = Trainer(params=tf_lib.init_lm(jax.random.PRNGKey(99), cfg,
                                            dtype=jnp.float32).params,
                      pipeline=mk(),
                      train_cfg=TrainConfig(num_steps=6, log_every=1), **common)
        assert tr2.maybe_restore()
        assert tr2.step_num == 3
        assert tr2.pipeline.state == {"step": 3}

    def test_preemption_checkpoints_synchronously(self, tmp_path):
        cfg, params = _tiny(seed=4)
        pipe = make_pipeline(DataConfig(vocab=64, seq_len=16, global_batch=4))
        tr = Trainer(loss_fn=lambda p, b: tf_lib.loss_fn(p, cfg, b),
                     params=params, opt_cfg=AdamWConfig(lr=1e-3),
                     train_cfg=TrainConfig(num_steps=100, log_every=50,
                                           checkpoint_every=1000),
                     pipeline=pipe,
                     ckpt_cfg=CheckpointConfig(str(tmp_path)))
        # simulate SIGTERM after the first step via the heartbeat hook
        orig = tr._jit_step

        def step_then_preempt(*a):
            out = orig(*a)
            tr._preempted = True
            return out
        tr._jit_step = step_then_preempt
        tr.run()
        assert tr.ckpt.latest_step() == tr.step_num
        assert tr.step_num >= 1
