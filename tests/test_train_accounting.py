"""Energy-accounting regression for the training fast path (DESIGN.md §13).

Pins the cost model: training StepMetrics byte/FLOP totals must match
hand-computed values for a tiny config, and the accountant must report
backward-phase energy separately from (and, with the documented 2x FLOPs
ratio, larger than) the forward phase.
"""

import jax
import jax.numpy as jnp
import pytest

from repro.core import accounting, energy
from repro.core import hw
from repro.data import DataConfig, make_pipeline
from repro.models import costing
from repro.models import transformer as tf_lib
from repro.optim import AdamWConfig, init_opt_state
from repro.train import TrainEngine, TrainEngineConfig

# tiny config, small enough to hand-count every matmul weight
D, H, KV, DFF, VOCAB, SEQ, BATCH = 16, 2, 2, 32, 32, 8, 2


def _cfg():
    return tf_lib.LMConfig(name="tiny", d_model=D, n_heads=H, n_kv_heads=KV,
                           d_ff=DFF, vocab=VOCAB,
                           pattern=(tf_lib.BlockSpec(),), repeats=1,
                           remat="none", vocab_pad_multiple=1)


def _params(cfg):
    return tf_lib.init_lm(jax.random.PRNGKey(0), cfg,
                          dtype=jnp.float32).params


def _hand_matmul_elems(cfg):
    """Every matmul weight in the one-block model, counted by hand:
    wq/wk/wv (D*D each: head_dim = D/H, H heads), wo (D*D), gated MLP
    (3 * D*DFF), plus the tied unembedding (VOCAB*D)."""
    head = cfg.resolved_head_dim
    attn = cfg.d_model * cfg.n_heads * head * 2          # wq + wo
    attn += cfg.d_model * cfg.n_kv_heads * head * 2      # wk + wv
    mlp = 3 * cfg.d_model * cfg.d_ff                     # w_in, w_gate, w_out
    unembed = cfg.vocab * cfg.d_model                    # tied embedding
    return attn + mlp + unembed


class TestCostModel:
    def test_matmul_elems_match_hand_count(self):
        cfg = _cfg()
        params = _params(cfg)
        assert costing.matmul_weight_elems(params, cfg) == \
            _hand_matmul_elems(cfg)

    def test_step_cost_matches_hand_computed(self):
        cfg = _cfg()
        params = _params(cfg)
        opt_state = init_opt_state(params, AdamWConfig(lr=1e-3))
        cost = costing.lm_train_step_cost(params, cfg, batch=BATCH,
                                          seq_len=SEQ, opt_state=opt_state)
        tokens = BATCH * SEQ
        w = _hand_matmul_elems(cfg)
        attn_dims = cfg.n_heads * cfg.resolved_head_dim
        # forward: 2 FLOPs per weight element per token + the causal
        # attention term 2 * n_attn_layers * (H*Dh) * S per token
        fwd = (2.0 * w + 2.0 * 1 * attn_dims * SEQ) * tokens
        assert cost.fwd_flops == pytest.approx(fwd)
        assert cost.bwd_flops == pytest.approx(2.0 * fwd)
        weight_bytes = sum(l.nbytes for l in jax.tree.leaves(params))
        n_params = sum(l.size for l in jax.tree.leaves(params))
        grad_bytes = 4.0 * n_params
        opt_bytes = sum(l.nbytes for l in jax.tree.leaves(opt_state))
        assert cost.fwd_bytes == pytest.approx(weight_bytes)
        assert cost.bwd_bytes == pytest.approx(weight_bytes + grad_bytes)
        assert cost.opt_bytes == pytest.approx(
            grad_bytes + 2.0 * opt_bytes + 2.0 * weight_bytes)
        assert cost.tokens == tokens and cost.samples == BATCH

    def test_scaled(self):
        c = energy.TrainStepCost(1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0)
        s = c.scaled(3)
        assert (s.fwd_flops, s.bwd_flops, s.fwd_bytes, s.bwd_bytes,
                s.opt_bytes, s.tokens, s.samples) == \
            (3.0, 6.0, 9.0, 12.0, 15.0, 18.0, 21.0)


class TestPhaseEnergy:
    def test_phase_split_formula(self):
        cost = energy.TrainStepCost(fwd_flops=1e9, bwd_flops=2e9,
                                    fwd_bytes=1e6, bwd_bytes=3e6,
                                    opt_bytes=2e6)
        ph = energy.train_phase_energy_j(cost)
        spec = hw.TPU_V5E
        assert ph["fwd_j"] == pytest.approx(
            1e9 * spec.power.active_w / spec.peak_flops
            + energy.dram_energy_j(1e6))
        assert ph["bwd_j"] == pytest.approx(
            2e9 * spec.power.active_w / spec.peak_flops
            + energy.dram_energy_j(3e6))
        assert ph["opt_j"] == pytest.approx(energy.dram_energy_j(2e6))
        assert ph["total_j"] == pytest.approx(
            ph["fwd_j"] + ph["bwd_j"] + ph["opt_j"])


class TestAccountantTrainLedger:
    def _run(self, steps=4, tick=2):
        cfg = _cfg()
        acct = accounting.CarbonAccountant(accounting.AccountantConfig(
            device="tpu_v5e", n_devices=1))
        eng = TrainEngine.for_lm(
            _params(cfg), cfg, opt_cfg=AdamWConfig(lr=1e-3),
            pipeline=make_pipeline(DataConfig(
                vocab=VOCAB, seq_len=SEQ, global_batch=BATCH,
                source="markov")),
            engine_cfg=TrainEngineConfig(steps_per_tick=tick),
            accountant=acct)
        eng.run(steps)
        return eng, acct

    def test_totals_are_per_step_cost_times_steps(self):
        eng, acct = self._run(steps=4, tick=2)
        rep = acct.train_report()
        c = eng.cost
        assert rep["steps"] == 4
        assert rep["fwd_flops"] == pytest.approx(4 * c.fwd_flops)
        assert rep["bwd_flops"] == pytest.approx(4 * c.bwd_flops)
        assert rep["fwd_bytes"] == pytest.approx(4 * c.fwd_bytes)
        assert rep["bwd_bytes"] == pytest.approx(4 * c.bwd_bytes)
        assert rep["opt_bytes"] == pytest.approx(4 * c.opt_bytes)
        assert rep["samples"] == 4 * BATCH

    def test_backward_reported_separately_and_dominates(self):
        _, acct = self._run()
        rep = acct.train_report()
        assert rep["bwd_j"] > rep["fwd_j"] > 0
        assert rep["bwd_fwd_ratio"] > 1.5
        assert rep["j_per_step"] == pytest.approx(rep["total_j"] / 4)
        assert rep["j_per_sample"] == pytest.approx(
            rep["total_j"] / rep["samples"])

    def test_train_ledger_in_full_report_and_grand_totals(self):
        eng, acct = self._run(steps=2, tick=2)
        rep = acct.report()
        assert "train" in rep
        c = eng.cost.scaled(2)
        assert rep["bytes_moved"] == pytest.approx(
            c.fwd_bytes + c.bwd_bytes + c.opt_bytes)
        assert rep["modeled_flops"] == pytest.approx(
            c.fwd_flops + c.bwd_flops)
        assert rep["tokens"] == 2 * BATCH * SEQ

    def test_no_train_block_without_training(self):
        acct = accounting.CarbonAccountant(accounting.AccountantConfig())
        assert acct.train_report() is None
        assert "train" not in acct.report()
