"""Fused TrainEngine tick vs the host-loop reference step (train/loop.py).

The contract: one engine tick scanning K optimizer steps must be
step-identical (loss + param update within per-dtype tolerance — bit-exact
on CPU fp32) to K iterations of make_train_step, and training through the
engine must actually learn (loss decreases over 20 steps on the Markov
stream).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import accounting
from repro.data import DataConfig, make_pipeline
from repro.models import transformer as tf_lib
from repro.optim import AdamWConfig, init_opt_state
from repro.train import (TrainEngine, TrainEngineConfig, make_train_step)

VOCAB, SEQ, BATCH = 64, 16, 4


def _cfg(**kw):
    base = dict(name="t", d_model=32, n_heads=2, n_kv_heads=2, d_ff=64,
                vocab=VOCAB, pattern=(tf_lib.BlockSpec(),), repeats=2,
                remat="none", vocab_pad_multiple=1)
    base.update(kw)
    return tf_lib.LMConfig(**base)


def _params(cfg, seed=0):
    return tf_lib.init_lm(jax.random.PRNGKey(seed), cfg,
                          dtype=jnp.float32).params


def _pipe(seed=0):
    return make_pipeline(DataConfig(vocab=VOCAB, seq_len=SEQ,
                                    global_batch=BATCH, seed=seed,
                                    source="markov"))


def _engine(cfg, opt, k, **kw):
    return TrainEngine.for_lm(_params(cfg), cfg, opt_cfg=opt,
                              pipeline=_pipe(),
                              engine_cfg=TrainEngineConfig(steps_per_tick=k),
                              **kw)


class TestStepParity:
    def test_tick_matches_loop_steps(self):
        """One fused 6-step tick == six host-loop reference steps."""
        cfg = _cfg()
        opt = AdamWConfig(lr=2e-3)
        eng = _engine(cfg, opt, k=6)
        last = eng.run(6)

        step = jax.jit(make_train_step(
            lambda p, b: tf_lib.loss_fn(p, cfg, b), opt))
        params = _params(cfg)
        state = init_opt_state(params, opt)
        pipe = _pipe()
        losses = []
        for i in range(6):
            batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(i).items()}
            params, state, metrics = step(params, state, batch)
            losses.append(float(metrics["loss"]))

        assert last["loss"] == pytest.approx(losses[-1], rel=1e-6)
        diffs = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                             eng.params, params)
        assert max(jax.tree.leaves(diffs)) <= 1e-6
        sdiff = jax.tree.map(
            lambda a, b: float(jnp.max(jnp.abs(
                a.astype(jnp.float32) - b.astype(jnp.float32)))),
            eng.opt_state["m"], state["m"])
        assert max(jax.tree.leaves(sdiff)) <= 1e-6

    def test_partial_tick_and_multi_tick_agree(self):
        """12 steps as 3 ticks of 4 == 12 steps as 2 ticks of 8+4 (the
        remainder tick compiles separately but computes the same stream)."""
        cfg = _cfg()
        opt = AdamWConfig(lr=1e-3)
        a = _engine(cfg, opt, k=4)
        a.run(12)
        b = _engine(cfg, opt, k=8)
        b.run(12)
        diffs = jax.tree.map(lambda x, y: float(jnp.max(jnp.abs(x - y))),
                             a.params, b.params)
        assert max(jax.tree.leaves(diffs)) <= 1e-6
        assert a.step_num == b.step_num == 12

    def test_tick_stays_fused(self):
        """One trace per scan length; one host readback per tick."""
        cfg = _cfg()
        eng = _engine(cfg, AdamWConfig(lr=1e-3), k=4)
        eng.run(8)          # 2 ticks, same scan length
        assert eng.tick_trace_count == 1
        assert eng.host_readbacks == 2
        eng.run(2)          # remainder tick: one new trace
        assert eng.tick_trace_count == 2
        assert eng.host_readbacks == 3


class TestLearning:
    def test_loss_decreases_over_20_steps(self):
        cfg = _cfg()
        eng = _engine(cfg, AdamWConfig(lr=5e-3), k=5)
        eng.run(20)
        first = eng.metrics_log[0]
        last = eng.metrics_log[-1]
        assert last.loss < first.loss_mean - 0.1, (
            first.loss_mean, last.loss)

    def test_schedule_advances_across_ticks(self):
        """The lr schedule sees the global step, not the within-tick step."""
        from repro.optim.schedules import warmup_cosine
        cfg = _cfg()
        opt = AdamWConfig(lr=warmup_cosine(1e-2, 10, 40))
        eng = _engine(cfg, opt, k=4)
        r1 = eng.run(4)
        r2 = eng.run(4)
        assert 0 < r1["lr"] < r2["lr"]   # still in warmup, monotonic


class TestMetricsAndAccounting:
    def test_metrics_and_accountant_billing(self):
        cfg = _cfg()
        acct = accounting.CarbonAccountant(accounting.AccountantConfig(
            device="tpu_v5e", n_devices=1))
        eng = _engine(cfg, AdamWConfig(lr=1e-3), k=4, accountant=acct)
        eng.run(8)
        assert len(eng.metrics_log) == 2
        m = eng.metrics_log[0]
        assert m.steps == 4
        assert m.tokens == 4 * BATCH * SEQ
        assert m.samples == 4 * BATCH
        assert m.fwd_flops > 0 and m.bwd_flops == 2.0 * m.fwd_flops
        assert m.bytes_moved > 0
        rep = acct.train_report()
        assert rep["steps"] == 8
        assert rep["fwd_flops"] == pytest.approx(2 * m.fwd_flops)
        s = eng.summary()
        assert s["steps"] == 8 and s["ticks"] == 2

    def test_run_requires_pipeline(self):
        cfg = _cfg()
        eng = TrainEngine(
            loss_fn=lambda p, b: tf_lib.loss_fn(p, cfg, b),
            params=_params(cfg), opt_cfg=AdamWConfig(lr=1e-3))
        with pytest.raises(AssertionError):
            eng.run(1)


class TestFlashVjpRoute:
    def test_engine_flash_vjp_matches_sdpa_engine(self):
        """The engine with flash-VJP attention (interpret mode) computes the
        same updates as the sdpa engine — the kernel route is numerics-
        neutral end to end."""
        cfg = _cfg(repeats=1)
        opt = AdamWConfig(lr=2e-3)
        ref = _engine(cfg, opt, k=2)
        ref.run(2)
        fast = TrainEngine.for_lm(
            _params(cfg), cfg, opt_cfg=opt, pipeline=_pipe(),
            engine_cfg=TrainEngineConfig(steps_per_tick=2,
                                         use_flash_vjp=True))
        assert fast.model_cfg.flash_train
        fast.run(2)
        diffs = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                             ref.params, fast.params)
        assert max(jax.tree.leaves(diffs)) <= 2e-5
