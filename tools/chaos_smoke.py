"""Quick chaos smoke: every transient fault kind must drain with streams
identical to the fault-free baseline (process_kill has no in-tick
recovery — its smoke is serve_bench --chaos --fault-kind process_kill).
Dev tool — the real gate is tests/test_serve_faults.py +
benchmarks/serve_bench.py --chaos."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.models import transformer as tf_lib
from repro.serve import ServeConfig, ServeEngine
from repro.serve.faults import TRANSIENT_FAULT_KINDS, FaultPlan

cfg = tf_lib.LMConfig(name="t", d_model=48, n_heads=4, n_kv_heads=2,
                      d_ff=96, vocab=61, pattern=(tf_lib.BlockSpec(),),
                      repeats=2, remat="none", vocab_pad_multiple=1)
params = tf_lib.init_lm(jax.random.PRNGKey(0), cfg, dtype=jnp.float32).params
PROMPTS = [np.arange(15), np.arange(11) + 7, np.arange(8) + 30]


def run(plan=None):
    eng = ServeEngine(params, cfg, ServeConfig(
        max_slots=2, max_len=64, paged=True, page_size=4, faults=plan))
    for p in PROMPTS:
        eng.submit(p, max_tokens=8)
    done = eng.run_until_drained(max_ticks=400)
    return eng, {r.uid: list(r.generated) for r in done}


_, base = run()
print("baseline:", base)
for kind in TRANSIENT_FAULT_KINDS:
    plan = FaultPlan.single(kind, tick=2, seed=11, slot=1)
    eng, got = run(plan)
    s = eng.summary()
    ident = got == base
    print(f"{kind:16s} inj={s['faults_injected']} quar={s['quarantined']} "
          f"shed={s['shed']} rec_j={s['recovery_j']:.3e} identical={ident}")
    assert ident, (kind, got, base)
print("ALL PASS")
