#!/usr/bin/env python
"""CI skip-count gate: fail if pytest skipped more tests than the committed
baseline allows.

Usage: python tools/check_skips.py <pytest-output.txt> <baseline-file>

The baseline file holds one integer — the maximum allowed skip count in the
full-dependency CI environment (0: with hypothesis installed, every
property test runs; a rising skip count means a dependency or marker
silently regressed). Local bare-environment runs legitimately skip the
hypothesis-backed tests via the conftest shim; this gate only runs in CI.
"""

from __future__ import annotations

import re
import sys


def skip_count(report: str) -> int:
    # the summary line looks like "282 passed, 9 skipped in 415.97s"
    m = re.findall(r"(\d+) skipped", report)
    return int(m[-1]) if m else 0


def main() -> int:
    if len(sys.argv) != 3:
        print(__doc__)
        return 2
    with open(sys.argv[1]) as f:
        report = f.read()
    with open(sys.argv[2]) as f:
        baseline = int(f.read().strip())
    n = skip_count(report)
    print(f"skipped: {n} (baseline allows {baseline})")
    if n > baseline:
        print("FAIL: skip count rose above the committed baseline — a "
              "dependency (hypothesis?) or marker regressed. If the new "
              "skips are intentional, update tests/skip_baseline.txt in "
              "the same PR and say why.")
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
