#!/usr/bin/env python
"""repro-lint CLI: run the invariant passes, diff against the baseline.

Usage (what `make lint` and the CI lint job run):

    PYTHONPATH=src python tools/repro_lint.py --baseline tools/lint_baseline.txt

Exit codes: 0 = clean modulo baseline; 1 = NEW findings (or stale
baseline entries under --strict); 2 = usage error.

The baseline holds *justified* suppressions keyed by line-number-free
fingerprints (see src/repro/lint/base.py). New findings must be fixed or
justified in the same PR; stale entries (violation fixed, entry left
behind) warn and should be deleted. ``--write-baseline`` regenerates the
file from current findings for bootstrap; every entry it writes carries a
TODO justification that review should replace.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

_HERE = os.path.dirname(os.path.abspath(__file__))
_ROOT = os.path.dirname(_HERE)
sys.path.insert(0, os.path.join(_ROOT, "src"))

from repro.lint import (Context, PASSES, load_baseline, run_passes,  # noqa: E402
                        split_by_baseline, write_baseline)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="repro_lint", description=__doc__)
    ap.add_argument("--root", default=_ROOT,
                    help="repo root to scan (default: this repo)")
    ap.add_argument("--baseline", default=None,
                    help="path to the justified-suppressions file")
    ap.add_argument("--write-baseline", action="store_true",
                    help="regenerate the baseline from current findings")
    ap.add_argument("--passes", default=None,
                    help="comma-separated pass names (default: all): " +
                    ", ".join(PASSES))
    ap.add_argument("--report", default=None,
                    help="write a JSON findings report (CI artifact)")
    ap.add_argument("--format", choices=("text", "json"), default="text")
    ap.add_argument("--strict", action="store_true",
                    help="stale baseline entries also fail")
    args = ap.parse_args(argv)

    names = None
    if args.passes:
        names = [p.strip() for p in args.passes.split(",") if p.strip()]
        unknown = [n for n in names if n not in PASSES]
        if unknown:
            print(f"repro-lint: unknown pass(es): {', '.join(unknown)}; "
                  f"known: {', '.join(PASSES)}", file=sys.stderr)
            return 2

    ctx = Context.for_root(args.root)
    findings = run_passes(ctx, names)

    if args.write_baseline:
        if not args.baseline:
            print("repro-lint: --write-baseline requires --baseline",
                  file=sys.stderr)
            return 2
        write_baseline(args.baseline, findings)
        print(f"repro-lint: wrote {len(findings)} fingerprint(s) to "
              f"{args.baseline}")
        return 0

    baseline = load_baseline(args.baseline) if args.baseline else {}
    new, suppressed, stale = split_by_baseline(findings, baseline)

    if args.report:
        payload = {
            "total": len(findings),
            "new": [f.__dict__ | {"fingerprint": f.fingerprint}
                    for f in new],
            "suppressed": [f.__dict__ | {
                "fingerprint": f.fingerprint,
                "justification": baseline.get(f.fingerprint, "")}
                for f in suppressed],
            "stale_baseline_entries": stale,
        }
        with open(args.report, "w", encoding="utf-8") as fh:
            json.dump(payload, fh, indent=2, sort_keys=True)

    if args.format == "json":
        print(json.dumps([f.__dict__ | {"fingerprint": f.fingerprint}
                          for f in new], indent=2, sort_keys=True))
    else:
        for f in new:
            print(f.render())
        if suppressed:
            print(f"repro-lint: {len(suppressed)} finding(s) suppressed "
                  f"by baseline")
        for fp in stale:
            print(f"repro-lint: stale baseline entry (violation fixed — "
                  f"delete the line): {fp}")

    if new:
        print(f"repro-lint: FAIL — {len(new)} new finding(s). Fix them or "
              f"add a justified line to the baseline "
              f"({args.baseline or 'tools/lint_baseline.txt'}).",
              file=sys.stderr)
        return 1
    if stale and args.strict:
        print(f"repro-lint: FAIL (--strict) — {len(stale)} stale baseline "
              f"entr(ies).", file=sys.stderr)
        return 1
    print(f"repro-lint: OK — {len(findings)} finding(s), all baselined; "
          f"{len(PASSES) if names is None else len(names)} pass(es).")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
